//! The versioned binary snapshot of one streaming session's carried
//! state.
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! b"PFRMSNAP" | u32 version | u32 header_len | JSON header
//!            | u64 payload_len | payload (PFRMTENS container)
//!            | u32 crc32 over every preceding byte
//! ```
//!
//! The JSON header carries the identity and geometry — session id,
//! stream position, per-state token counts and redraw epochs, and the
//! [`ModelFingerprint`] (which includes the per-layer attention-kernel
//! configs: kind, M, ORF mechanism, redraw seed/schedule); the payload
//! is a `runtime::TensorFile` container holding the actual f32 tensors:
//! one `state:{layer}:{head}` entry per carried M×(d_h+1) prefix sum,
//! plus the vocab-sized `prev_row` context row once the stream has
//! consumed a chunk. The trailing CRC32 (IEEE) makes truncation and
//! bit-rot loud: a snapshot either decodes to exactly the captured
//! state or refuses to decode.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::favor::KernelConfig;
use crate::jsonx::{num, obj, s, Json};
use crate::runtime::TensorFile;
use crate::stream::{ChunkScorer, StatePrecision, StreamState};
use crate::tensor::Mat;
use crate::train::{NativeAttention, NativeModel};

const MAGIC: &[u8; 8] = b"PFRMSNAP";

/// Bump on any incompatible change to the envelope or header schema;
/// readers reject other versions loudly instead of guessing.
/// v2: per-layer kernel configs replace the single `m` field, and every
/// carried state records its redraw epoch.
/// v3: the fingerprint embeds the state storage precision; bf16 states
/// serialize their raw bf16 words (`qstate:{l}:{h}`, two words packed
/// per f32 bit pattern) plus per-state requantize scales, so a
/// quantized snapshot costs half the payload of an f32 one and f32/bf16
/// snapshots refuse each other.
pub const SNAPSHOT_VERSION: u32 = 3;

/// IEEE CRC32 (reflected, init/xorout 0xFFFFFFFF) — bitwise variant;
/// snapshots are tens of kilobytes, so a lookup table buys nothing.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in bytes {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

/// The model a snapshot was captured from: the carried-state geometry
/// plus a digest over every parameter byte. Restoring validates both
/// against the target model, so a snapshot can only rehydrate into the
/// exact stack it came from — two models with identical shapes but
/// different weights (or resampled FAVOR features) would turn the
/// carried prefix sums into silently wrong scores.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ModelFingerprint {
    /// number of transformer layers
    pub layers: usize,
    /// attention heads per layer
    pub heads: usize,
    /// per-head value dimension d_h
    pub d_head: usize,
    /// vocabulary size (length of the carried context row)
    pub vocab: usize,
    /// per-layer attention-kernel identity (kind, M, ORF mechanism,
    /// redraw seed/schedule): a snapshot refuses restore into a model
    /// whose kernel layer differs in *any* field, even when every
    /// tensor shape matches — e.g. an identical stack with a different
    /// redraw schedule would reset context at different positions
    pub kernels: Vec<KernelConfig>,
    /// [`NativeModel::weights_digest`] over every parameter byte
    pub weights: u64,
    /// storage precision the carried states were captured under —
    /// embedded here so f32 and bf16 snapshots can never be confused
    /// (the adopting [`crate::stream::SessionManager`] additionally
    /// refuses a precision that differs from its configured mode)
    pub precision: StatePrecision,
}

impl ModelFingerprint {
    /// Fingerprint a streamable model (at the default f32 state
    /// precision — see [`Self::precision`]). Errors on non-FAVOR
    /// attention — such a model has no carried state to snapshot in the
    /// first place.
    pub fn of(model: &NativeModel) -> Result<ModelFingerprint> {
        let NativeAttention::Favor(kernels) = &model.attention else {
            bail!("only FAVOR models carry snapshottable stream state");
        };
        Ok(ModelFingerprint {
            layers: model.n_layers(),
            heads: model.n_heads,
            d_head: model.d_model / model.n_heads,
            vocab: model.vocab_size,
            kernels: kernels.iter().map(|k| k.config().clone()).collect(),
            weights: model.weights_digest(),
            precision: StatePrecision::F32,
        })
    }

    fn to_json(&self) -> Json {
        obj(vec![
            ("layers", num(self.layers as f64)),
            ("heads", num(self.heads as f64)),
            ("d_head", num(self.d_head as f64)),
            ("vocab", num(self.vocab as f64)),
            ("kernels", Json::Arr(self.kernels.iter().map(KernelConfig::to_json).collect())),
            // hex string: a u64 digest does not fit losslessly in a
            // JSON f64 number
            ("weights", s(&format!("{:016x}", self.weights))),
            ("precision", s(self.precision.name())),
        ])
    }

    fn from_json(j: &Json) -> Result<ModelFingerprint> {
        let layers = j.req("layers")?.as_usize()?;
        let kernels = j
            .req("kernels")?
            .as_arr()?
            .iter()
            .map(KernelConfig::from_json)
            .collect::<Result<Vec<_>>>()?;
        if kernels.len() != layers {
            bail!("fingerprint lists {} kernel(s) for {layers} layer(s)", kernels.len());
        }
        let precision_name = j.req("precision")?.as_str()?;
        let precision = StatePrecision::parse(precision_name)
            .ok_or_else(|| anyhow::anyhow!("unknown state precision '{precision_name}'"))?;
        Ok(ModelFingerprint {
            layers,
            heads: j.req("heads")?.as_usize()?,
            d_head: j.req("d_head")?.as_usize()?,
            vocab: j.req("vocab")?.as_usize()?,
            kernels,
            weights: u64::from_str_radix(j.req("weights")?.as_str()?, 16)
                .context("fingerprint weight digest is not hex")?,
            precision,
        })
    }
}

/// Everything needed to resume one session in another process: the
/// serializable image of a `ChunkScorer` (minus the shared model).
#[derive(Clone, Debug)]
pub struct SessionSnapshot {
    /// session id the state belongs to
    pub session: String,
    /// global stream position (tokens consumed)
    pub pos: usize,
    /// carried cross-chunk context row (previous chunk's last logits)
    pub prev_row: Option<Vec<f32>>,
    /// geometry of the model the state was captured from
    pub fingerprint: ModelFingerprint,
    /// per-layer per-head FAVOR prefix sums
    pub states: Vec<Vec<StreamState>>,
}

impl SessionSnapshot {
    /// Capture a live scorer's carried state (at the scorer's own
    /// storage precision — the fingerprint records which).
    pub fn capture(session: &str, scorer: &ChunkScorer) -> Result<SessionSnapshot> {
        let mut fingerprint = ModelFingerprint::of(scorer.model())?;
        fingerprint.precision = scorer.precision();
        Ok(SessionSnapshot {
            session: session.to_string(),
            pos: scorer.tokens_seen(),
            prev_row: scorer.prev_row().map(<[f32]>::to_vec),
            fingerprint,
            states: scorer.states().to_vec(),
        })
    }

    /// The storage precision the snapshot's states were captured under.
    pub fn precision(&self) -> StatePrecision {
        self.fingerprint.precision
    }

    /// Rehydrate into a scorer over `model`, refusing a geometry
    /// mismatch — restoring state into the wrong model would stream
    /// plausible-looking garbage. The scorer resumes at the snapshot's
    /// own storage precision; whether that precision is *acceptable* is
    /// the adopting manager's policy ([`crate::stream::SessionConfig`]).
    pub fn into_scorer(self, model: Arc<NativeModel>) -> Result<ChunkScorer> {
        let mut target = ModelFingerprint::of(&model)?;
        // precision is a property of the captured session, not of the
        // model: align it so the comparison below checks model identity
        target.precision = self.fingerprint.precision;
        if target != self.fingerprint {
            bail!(
                "snapshot for session '{}' was captured from {:?}, target model is {:?}",
                self.session,
                self.fingerprint,
                target
            );
        }
        ChunkScorer::from_parts(model, self.states, self.prev_row, self.pos)
            .with_context(|| format!("rehydrating session '{}'", self.session))
    }

    /// Encode into the `PFRMSNAP` envelope.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut tensors = TensorFile::default();
        let mut tokens_seen = Vec::new();
        let mut epochs = Vec::new();
        let mut scale_bits = Vec::new();
        for (li, layer) in self.states.iter().enumerate() {
            for (hi, st) in layer.iter().enumerate() {
                tokens_seen.push(num(st.tokens_seen() as f64));
                epochs.push(num(st.epoch() as f64));
                match st.precision() {
                    StatePrecision::F32 => {
                        let dense = st.dense();
                        tensors.entries.push((
                            format!("state:{li}:{hi}"),
                            vec![dense.rows, dense.cols],
                            dense.data,
                        ));
                    }
                    StatePrecision::Bf16 => {
                        // half-size payload: two raw bf16 words packed
                        // per f32 bit pattern (the tensor container is
                        // bit-preserving, never arithmetic). The
                        // requantize scale rides in the header as exact
                        // f32 bits
                        scale_bits.push(num(st.scale().to_bits() as f64));
                        let words = st.quant_state();
                        let packed: Vec<f32> = words
                            .chunks(2)
                            .map(|pair| {
                                let lo = pair[0] as u32;
                                let hi = pair.get(1).map_or(0u32, |&w| w as u32);
                                f32::from_bits(lo | (hi << 16))
                            })
                            .collect();
                        tensors.entries.push((
                            format!("qstate:{li}:{hi}"),
                            vec![packed.len()],
                            packed,
                        ));
                    }
                }
            }
        }
        if let Some(row) = &self.prev_row {
            tensors.entries.push(("prev_row".to_string(), vec![row.len()], row.clone()));
        }
        let mut header_fields = vec![
            ("session", s(&self.session)),
            ("pos", num(self.pos as f64)),
            ("has_prev_row", Json::Bool(self.prev_row.is_some())),
            ("fingerprint", self.fingerprint.to_json()),
            ("tokens_seen", Json::Arr(tokens_seen)),
            ("epochs", Json::Arr(epochs)),
        ];
        if self.fingerprint.precision == StatePrecision::Bf16 {
            header_fields.push(("scale_bits", Json::Arr(scale_bits)));
        }
        let header = obj(header_fields).to_string();
        let payload = tensors.to_bytes();

        let mut out = Vec::with_capacity(28 + header.len() + payload.len());
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&SNAPSHOT_VERSION.to_le_bytes());
        out.extend_from_slice(&(header.len() as u32).to_le_bytes());
        out.extend_from_slice(header.as_bytes());
        out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        out.extend_from_slice(&payload);
        let crc = crc32(&out);
        out.extend_from_slice(&crc.to_le_bytes());
        out
    }

    /// Decode and verify a `PFRMSNAP` envelope. Every failure mode —
    /// wrong magic, unknown version, truncation anywhere, checksum
    /// mismatch, malformed header, missing or mis-shaped tensor — is a
    /// loud error; this function never returns a partially-restored
    /// state.
    pub fn from_bytes(bytes: &[u8]) -> Result<SessionSnapshot> {
        if bytes.len() < 16 || &bytes[..8] != MAGIC {
            bail!("not a PFRMSNAP session snapshot");
        }
        let version = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
        if version != SNAPSHOT_VERSION {
            bail!("unsupported snapshot version {version} (this build reads {SNAPSHOT_VERSION})");
        }
        let header_len = u32::from_le_bytes(bytes[12..16].try_into().unwrap()) as usize;
        let header_end = 16usize
            .checked_add(header_len)
            .filter(|e| e.checked_add(8).is_some_and(|x| x <= bytes.len()))
            .ok_or_else(|| anyhow::anyhow!("truncated snapshot header"))?;
        let payload_len =
            u64::from_le_bytes(bytes[header_end..header_end + 8].try_into().unwrap()) as usize;
        let payload_end = (header_end + 8)
            .checked_add(payload_len)
            .filter(|e| e.checked_add(4).is_some_and(|x| x <= bytes.len()))
            .ok_or_else(|| anyhow::anyhow!("truncated snapshot payload"))?;
        if payload_end + 4 != bytes.len() {
            bail!("snapshot has trailing garbage after the checksum");
        }
        let stored_crc = u32::from_le_bytes(bytes[payload_end..payload_end + 4].try_into().unwrap());
        let actual_crc = crc32(&bytes[..payload_end]);
        if stored_crc != actual_crc {
            bail!("snapshot checksum mismatch (stored {stored_crc:#010x}, computed {actual_crc:#010x}): file is corrupt");
        }

        let header = Json::parse(
            std::str::from_utf8(&bytes[16..header_end]).context("snapshot header is not UTF-8")?,
        )
        .context("snapshot header is not valid JSON")?;
        let session = header.req("session")?.as_str()?.to_string();
        let pos = header.req("pos")?.as_usize()?;
        let has_prev_row = header.req("has_prev_row")?.as_bool()?;
        let fingerprint = ModelFingerprint::from_json(header.req("fingerprint")?)?;
        let counts_of = |key: &str| -> Result<Vec<u64>> {
            let vals: Vec<u64> = header
                .req(key)?
                .as_arr()?
                .iter()
                .map(|v| v.as_f64().map(|n| n as u64))
                .collect::<Result<Vec<_>>>()?;
            if vals.len() != fingerprint.layers * fingerprint.heads {
                bail!(
                    "snapshot lists {} {key} entries, fingerprint implies {}",
                    vals.len(),
                    fingerprint.layers * fingerprint.heads
                );
            }
            Ok(vals)
        };
        let tokens_seen = counts_of("tokens_seen")?;
        let epochs = counts_of("epochs")?;
        let scale_bits = if fingerprint.precision == StatePrecision::Bf16 {
            counts_of("scale_bits")?
        } else {
            Vec::new()
        };

        let tensors = TensorFile::from_bytes(&bytes[header_end + 8..payload_end])
            .context("snapshot tensor payload")?;
        let dh = fingerprint.d_head;
        let mut states = Vec::with_capacity(fingerprint.layers);
        for li in 0..fingerprint.layers {
            // per-layer M: hybrid stacks carry differently-shaped sums
            let m = fingerprint.kernels[li].m;
            let mut layer = Vec::with_capacity(fingerprint.heads);
            for hi in 0..fingerprint.heads {
                let flat = li * fingerprint.heads + hi;
                match fingerprint.precision {
                    StatePrecision::F32 => {
                        let name = format!("state:{li}:{hi}");
                        let (shape, data) = tensors
                            .get(&name)
                            .ok_or_else(|| anyhow::anyhow!("snapshot is missing tensor {name}"))?;
                        if shape != [m, dh + 1].as_slice() {
                            bail!(
                                "tensor {name} has shape {shape:?}, expected [{m}, {}]",
                                dh + 1
                            );
                        }
                        layer.push(StreamState::from_parts(
                            m,
                            dh,
                            Mat::from_vec(m, dh + 1, data.to_vec()),
                            tokens_seen[flat],
                            epochs[flat],
                        ));
                    }
                    StatePrecision::Bf16 => {
                        let name = format!("qstate:{li}:{hi}");
                        let (shape, data) = tensors
                            .get(&name)
                            .ok_or_else(|| anyhow::anyhow!("snapshot is missing tensor {name}"))?;
                        let len = m * (dh + 1);
                        let packed_len = len.div_ceil(2);
                        if shape != [packed_len].as_slice() {
                            bail!("tensor {name} has shape {shape:?}, expected [{packed_len}]");
                        }
                        let mut words = Vec::with_capacity(len);
                        for &v in data {
                            let bits = v.to_bits();
                            words.push((bits & 0xffff) as u16);
                            if words.len() < len {
                                words.push((bits >> 16) as u16);
                            }
                        }
                        if words.len() != len {
                            bail!("tensor {name} unpacked {} words, expected {len}", words.len());
                        }
                        layer.push(StreamState::from_quant_parts(
                            m,
                            dh,
                            words,
                            f32::from_bits(scale_bits[flat] as u32),
                            tokens_seen[flat],
                            epochs[flat],
                        ));
                    }
                }
            }
            states.push(layer);
        }
        let prev_row = if has_prev_row {
            let (shape, data) = tensors
                .get("prev_row")
                .ok_or_else(|| anyhow::anyhow!("snapshot is missing its context row"))?;
            if shape != [fingerprint.vocab].as_slice() {
                bail!(
                    "context row has shape {shape:?}, expected [{}]",
                    fingerprint.vocab
                );
            }
            Some(data.to_vec())
        } else {
            None
        };
        Ok(SessionSnapshot { session, pos, prev_row, fingerprint, states })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protein::vocab::{AA_BASE, N_AA};
    use crate::rng::Pcg64;
    use crate::train::SyntheticConfig;

    fn model(seed: u64) -> Arc<NativeModel> {
        let mut rng = Pcg64::new(seed);
        Arc::new(NativeModel::synthetic(&SyntheticConfig::default(), &mut rng))
    }

    fn tokens(n: usize, seed: u64) -> Vec<u8> {
        let mut rng = Pcg64::new(seed);
        (0..n).map(|_| AA_BASE + rng.below(N_AA) as u8).collect()
    }

    #[test]
    fn crc32_reference_vectors() {
        // the standard IEEE check value
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn roundtrip_resumes_bit_for_bit() {
        let m = model(1);
        let mut original = ChunkScorer::new(m.clone()).unwrap();
        original.advance(&tokens(37, 2)).unwrap();

        let snap = SessionSnapshot::capture("s", &original).unwrap();
        let bytes = snap.to_bytes();
        let mut restored = SessionSnapshot::from_bytes(&bytes)
            .unwrap()
            .into_scorer(m)
            .unwrap();
        assert_eq!(restored.tokens_seen(), 37);

        let next = tokens(23, 3);
        let a = original.advance(&next).unwrap();
        let b = restored.advance(&next).unwrap();
        assert_eq!(a.offset, b.offset);
        // bitwise, not approximately: restore must be exact
        let (abits, bbits): (Vec<u32>, Vec<u32>) = (
            a.logprob.iter().map(|v| v.to_bits()).collect(),
            b.logprob.iter().map(|v| v.to_bits()).collect(),
        );
        assert_eq!(abits, bbits, "restored session diverged from the original");
    }

    #[test]
    fn bf16_roundtrip_resumes_bit_for_bit_with_half_the_state_payload() {
        let m = model(41);
        let mut f32_scorer = ChunkScorer::new(m.clone()).unwrap();
        let mut original =
            ChunkScorer::new_with_precision(m.clone(), StatePrecision::Bf16).unwrap();
        f32_scorer.advance(&tokens(37, 42)).unwrap();
        original.advance(&tokens(37, 42)).unwrap();

        let f32_bytes = SessionSnapshot::capture("q", &f32_scorer).unwrap().to_bytes();
        let snap = SessionSnapshot::capture("q", &original).unwrap();
        assert_eq!(snap.precision(), StatePrecision::Bf16);
        let bytes = snap.to_bytes();
        // the quantized payload halves the state tensors (header and
        // context row are shared overhead)
        let state_f32 = f32_scorer.state_bytes();
        assert!(
            f32_bytes.len() - bytes.len() >= state_f32 / 2 - 64,
            "bf16 snapshot saves {} of {state_f32} state bytes",
            f32_bytes.len() - bytes.len()
        );

        let mut restored = SessionSnapshot::from_bytes(&bytes)
            .unwrap()
            .into_scorer(m)
            .unwrap();
        assert_eq!(restored.precision(), StatePrecision::Bf16);
        let next = tokens(23, 43);
        let a = original.advance(&next).unwrap();
        let b = restored.advance(&next).unwrap();
        let (abits, bbits): (Vec<u32>, Vec<u32>) = (
            a.logprob.iter().map(|v| v.to_bits()).collect(),
            b.logprob.iter().map(|v| v.to_bits()).collect(),
        );
        assert_eq!(abits, bbits, "restored bf16 session diverged from the original");
    }

    #[test]
    fn fingerprint_embeds_the_precision_mode() {
        let m = model(44);
        let f = ChunkScorer::new(m.clone()).unwrap();
        let q = ChunkScorer::new_with_precision(m, StatePrecision::Bf16).unwrap();
        let fp_f = SessionSnapshot::capture("a", &f).unwrap().fingerprint;
        let fp_q = SessionSnapshot::capture("a", &q).unwrap().fingerprint;
        assert_ne!(fp_f, fp_q, "precision must distinguish otherwise-equal fingerprints");
        assert_eq!(fp_f.precision, StatePrecision::F32);
        assert_eq!(fp_q.precision, StatePrecision::Bf16);
    }

    #[test]
    fn fresh_session_snapshot_has_no_context_row() {
        let m = model(4);
        let scorer = ChunkScorer::new(m.clone()).unwrap();
        let snap = SessionSnapshot::capture("fresh", &scorer).unwrap();
        assert!(snap.prev_row.is_none());
        let restored = SessionSnapshot::from_bytes(&snap.to_bytes())
            .unwrap()
            .into_scorer(m)
            .unwrap();
        assert_eq!(restored.tokens_seen(), 0);
    }

    #[test]
    fn every_truncation_is_rejected() {
        let m = model(5);
        let mut scorer = ChunkScorer::new(m).unwrap();
        scorer.advance(&tokens(16, 6)).unwrap();
        let bytes = SessionSnapshot::capture("t", &scorer).unwrap().to_bytes();
        for cut in [0, 7, 8, 12, 15, 16, bytes.len() / 2, bytes.len() - 1] {
            assert!(
                SessionSnapshot::from_bytes(&bytes[..cut]).is_err(),
                "truncation at {cut} must fail loudly"
            );
        }
    }

    #[test]
    fn bit_flips_fail_the_checksum() {
        let m = model(7);
        let mut scorer = ChunkScorer::new(m).unwrap();
        scorer.advance(&tokens(16, 8)).unwrap();
        let bytes = SessionSnapshot::capture("x", &scorer).unwrap().to_bytes();
        for pos in [9, 20, bytes.len() / 2, bytes.len() - 6] {
            let mut bad = bytes.clone();
            bad[pos] ^= 0x40;
            assert!(
                SessionSnapshot::from_bytes(&bad).is_err(),
                "bit flip at {pos} must fail loudly"
            );
        }
    }

    #[test]
    fn wrong_version_and_magic_are_rejected() {
        let m = model(9);
        let scorer = ChunkScorer::new(m).unwrap();
        let bytes = SessionSnapshot::capture("v", &scorer).unwrap().to_bytes();
        let mut wrong_magic = bytes.clone();
        wrong_magic[0] = b'X';
        assert!(SessionSnapshot::from_bytes(&wrong_magic).is_err());
        let mut wrong_version = bytes;
        wrong_version[8] = 99; // version is checked before the checksum
        assert!(SessionSnapshot::from_bytes(&wrong_version).is_err());
    }

    #[test]
    fn refuses_same_geometry_different_weights() {
        // identical architecture, different seed: geometry matches but
        // the weight digest must block the restore — the carried prefix
        // sums would otherwise produce silently wrong scores
        let donor = model(21);
        let impostor = model(22);
        let mut scorer = ChunkScorer::new(donor).unwrap();
        scorer.advance(&tokens(8, 23)).unwrap();
        let snap = SessionSnapshot::capture("w", &scorer).unwrap();
        let err = SessionSnapshot::from_bytes(&snap.to_bytes())
            .unwrap()
            .into_scorer(impostor)
            .unwrap_err();
        assert!(format!("{err:#}").contains("captured from"), "{err:#}");
    }

    #[test]
    fn refuses_a_different_kernel_config() {
        // identical weights and geometry, but the target's kernel layer
        // has a different redraw schedule: the carried sums would reset
        // at different positions, so restore must refuse. The kernel
        // config reaches the fingerprint both through `kernels` and the
        // weights digest (which folds in each kernel's signature).
        let mut rng_a = Pcg64::new(33);
        let mut rng_b = Pcg64::new(33);
        let donor = Arc::new(NativeModel::synthetic(&SyntheticConfig::default(), &mut rng_a));
        let rescheduled = Arc::new(NativeModel::synthetic(
            &SyntheticConfig { redraw_every: 64, ..Default::default() },
            &mut rng_b,
        ));
        let mut scorer = ChunkScorer::new(donor).unwrap();
        scorer.advance(&tokens(8, 34)).unwrap();
        let snap = SessionSnapshot::capture("k", &scorer).unwrap();
        assert_ne!(
            snap.fingerprint.kernels[0].redraw_every,
            64,
            "donor streams without a redraw schedule"
        );
        let err = SessionSnapshot::from_bytes(&snap.to_bytes())
            .unwrap()
            .into_scorer(rescheduled)
            .unwrap_err();
        assert!(format!("{err:#}").contains("captured from"), "{err:#}");
    }

    #[test]
    fn redraw_session_roundtrips_across_an_epoch_boundary() {
        // capture mid-stream after crossing a redraw boundary; the
        // restored scorer must continue bit-for-bit (epoch + sums + pos)
        let mut rng = Pcg64::new(35);
        let m = Arc::new(NativeModel::synthetic(
            &SyntheticConfig { redraw_every: 24, ..Default::default() },
            &mut rng,
        ));
        let mut original = ChunkScorer::new(m.clone()).unwrap();
        original.advance(&tokens(40, 36)).unwrap(); // epochs 0 -> 1 inside
        assert!(original.states()[0][0].epoch() > 0, "boundary must have been crossed");

        let snap = SessionSnapshot::capture("re", &original).unwrap();
        let mut restored =
            SessionSnapshot::from_bytes(&snap.to_bytes()).unwrap().into_scorer(m).unwrap();
        let next = tokens(30, 37); // crosses the epoch-2 boundary at 48
        let a = original.advance(&next).unwrap();
        let b = restored.advance(&next).unwrap();
        let (abits, bbits): (Vec<u32>, Vec<u32>) = (
            a.logprob.iter().map(|v| v.to_bits()).collect(),
            b.logprob.iter().map(|v| v.to_bits()).collect(),
        );
        assert_eq!(abits, bbits, "restored redraw session diverged");
    }

    #[test]
    fn refuses_a_mismatched_model() {
        let mut rng = Pcg64::new(10);
        let small = Arc::new(NativeModel::synthetic(
            &SyntheticConfig { d_model: 16, n_heads: 2, ..Default::default() },
            &mut rng,
        ));
        let mut scorer = ChunkScorer::new(model(11)).unwrap();
        scorer.advance(&tokens(8, 12)).unwrap();
        let snap = SessionSnapshot::capture("mm", &scorer).unwrap();
        let err = SessionSnapshot::from_bytes(&snap.to_bytes())
            .unwrap()
            .into_scorer(small)
            .unwrap_err();
        assert!(format!("{err:#}").contains("captured from"), "{err:#}");
    }
}
