//! Durable session persistence: checkpoint, spill-to-disk and migration
//! for streaming FAVOR state.
//!
//! Causal FAVOR compresses an unbounded prefix into a fixed
//! M×(d_h+1) prefix sum per (layer, head) — a few tens of kilobytes per
//! session no matter how many tokens have streamed through. That makes
//! a live session *cheap to make durable*: snapshot the prefix sums,
//! the carried cross-chunk context row and the stream position, and any
//! process holding the same weights can resume the stream bit-for-bit.
//! This module turns that observation into three capabilities:
//!
//! * [`snapshot`] — [`SessionSnapshot`], the versioned, checksummed
//!   binary snapshot of one session's carried state (`PFRMSNAP`
//!   envelope around a `runtime::TensorFile` tensor payload), plus
//!   [`ModelFingerprint`], which pins a snapshot to the model geometry
//!   it was captured from so it can never be rehydrated into a
//!   mismatched stack;
//! * [`checkpointer`] — [`Checkpointer`], a directory of snapshots with
//!   a crash-safe manifest (every write goes temp-file-then-rename, and
//!   every record carries the snapshot's byte length and CRC32, so a
//!   torn write is detected loudly instead of restoring garbage);
//! * the spill tier in `stream::SessionManager` — LRU eviction under a
//!   byte budget demotes cold sessions to a [`Checkpointer`] instead of
//!   destroying their context, and the next chunk for a spilled id
//!   transparently rehydrates it — and the migration APIs on
//!   `coordinator::Coordinator` (`checkpoint_all` / `restore_from`),
//!   which let a warm replica adopt another coordinator's sessions.
//!
//! See DESIGN.md §Durable session persistence for the byte-level format.

pub mod checkpointer;
pub mod snapshot;

pub use checkpointer::{Checkpointer, SnapshotRecord};
pub use snapshot::{crc32, ModelFingerprint, SessionSnapshot, SNAPSHOT_VERSION};
