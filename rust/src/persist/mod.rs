//! Durable session persistence: checkpoint, spill-to-disk and migration
//! for streaming FAVOR state.
//!
//! Causal FAVOR compresses an unbounded prefix into a fixed
//! M×(d_h+1) prefix sum per (layer, head) — a few tens of kilobytes per
//! session no matter how many tokens have streamed through. That makes
//! a live session *cheap to make durable*: snapshot the prefix sums,
//! the carried cross-chunk context row and the stream position, and any
//! process holding the same weights can resume the stream bit-for-bit.
//! This module turns that observation into three capabilities:
//!
//! * [`snapshot`] — [`SessionSnapshot`], the versioned, checksummed
//!   binary snapshot of one session's carried state (`PFRMSNAP`
//!   envelope around a `runtime::TensorFile` tensor payload), plus
//!   [`ModelFingerprint`], which pins a snapshot to the model geometry
//!   it was captured from so it can never be rehydrated into a
//!   mismatched stack;
//! * [`checkpointer`] — [`Checkpointer`], a directory of snapshots with
//!   a crash-safe, *generation-counted* manifest (every write goes
//!   temp-file-then-rename, every record carries the snapshot's byte
//!   length and CRC32 plus a delta-export dirty marker, so a torn write
//!   is detected loudly and a clean session can be retained across
//!   exports without re-snapshotting);
//! * [`spill`] — [`SpillTier`], the asynchronous write-back spill tier:
//!   LRU eviction in `stream::SessionManager` *enqueues* a demotion to
//!   a background writer thread instead of blocking the serving thread
//!   on an fsync; in-flight spills stay resident-readable until their
//!   write commits, and rehydration of one short-circuits to the
//!   resident copy;
//! * [`bundle`] — the `PFRMBNDL` envelope packing a whole export
//!   directory (manifest + snapshots) into one checksummed byte blob,
//!   so the networked serving tier (`net::router`) can ship a shard's
//!   sessions over TCP during a live drain/rebalance;
//! * the migration + export APIs on `coordinator::Coordinator`
//!   (`checkpoint_all` / `checkpoint_delta` / `restore_from`), which
//!   let a warm replica adopt another coordinator's sessions and let a
//!   hot export re-snapshot only the sessions that advanced since the
//!   previous one.
//!
//! See DESIGN.md §Durable session persistence for the byte-level format,
//! the write-back protocol and the delta-manifest generation scheme.

pub mod bundle;
pub mod checkpointer;
pub mod snapshot;
pub mod spill;

pub use bundle::{bundle_dir, unbundle_into, BUNDLE_VERSION};
pub use checkpointer::{Checkpointer, SnapshotRecord};
pub use snapshot::{crc32, ModelFingerprint, SessionSnapshot, SNAPSHOT_VERSION};
pub use spill::{SpillCounters, SpillTier};
