//! The asynchronous spill tier: write-back demotion of cold sessions to
//! disk, off the serving thread.
//!
//! PR 3's spill tier paid a full fsynced snapshot write inside
//! `SessionManager::advance_batch` every time eviction fired — a hot
//! checkpoint stalled every in-flight stream. This module moves the
//! write behind a dedicated writer thread:
//!
//! ```text
//!   serving thread                      spill-writer thread
//!   ──────────────                      ───────────────────
//!   evict: capture+encode ──channel──▶  write_atomic(.snap)
//!          park scorer in `pending`     publish record + retire entry
//!          (resident-readable!)         commit the manifest
//! ```
//!
//! Invariants:
//!
//! * **Write-back, not write-through** — until the background write
//!   commits, the evicted session's scorer stays parked in the
//!   `pending` map. Rehydration of a pending id takes the resident copy
//!   back (and thereby cancels the queued write), so an
//!   advance-after-evict never blocks on, or races with, disk.
//! * **Exactly-one-owner** — at any observable point a live session is
//!   resident in the manager, parked in `pending`, or committed in the
//!   tier. The writer publishes a finished write (in-memory record +
//!   committed-id mirror) and retires the pending entry in one critical
//!   section under the pending lock, so there is no window where a
//!   demoted session is invisible or where a take-back races a commit.
//! * **Shutdown drains** — dropping the tier closes the channel, and
//!   the writer finishes every queued job before exiting; nothing that
//!   was enqueued is lost on an orderly shutdown.
//! * **Failed writes degrade loudly, not leakily** — a session whose
//!   spill write fails is queued on a failure list that the
//!   `SessionManager` reaps at its next batch, converting it to the old
//!   synchronous path's loud eviction; parked scorers can never
//!   accumulate unboundedly behind a bad disk.
//! * **A closed id can never resurrect** — publication (in-memory
//!   record + committed mirror + pending retire) happens atomically
//!   under the pending lock, so a job whose session was closed or taken
//!   back after the pre-check is simply discarded, orphan file removed.

use std::collections::{BTreeSet, HashMap};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::obs::trace;
use crate::stream::ChunkScorer;
use crate::train::NativeModel;

use super::checkpointer::{snapshot_filename, Checkpointer, SnapshotRecord};
use super::snapshot::{crc32, SessionSnapshot};

/// A spill captured on the serving thread, parked in RAM until its
/// background write commits.
struct PendingSpill {
    scorer: ChunkScorer,
    /// the session's dirty generation at capture (travels with the
    /// snapshot so a rehydrated-but-unchanged session stays "clean"
    /// for delta exports)
    dirty_gen: u64,
    /// enqueue sequence number: a writer job commits only if the
    /// pending entry still carries its sequence — a take-back or a
    /// newer spill of the same id supersedes it
    seq: u64,
    /// encoded snapshot size, charged against the staging high-water
    /// mark while the entry is parked
    bytes: u64,
}

enum Job {
    Write {
        id: String,
        seq: u64,
        bytes: Vec<u8>,
        pos: u64,
        exporter: u64,
        dirty_gen: u64,
    },
    /// barrier: acked once every job queued before it has been handled
    Flush(Sender<()>),
}

/// Writer-side counters, written by the spill thread and read (lock-free)
/// by `SessionManager::stats`.
#[derive(Default)]
struct WriterStats {
    commits: AtomicU64,
    cancels: AtomicU64,
    write_failures: AtomicU64,
    write_nanos: AtomicU64,
}

/// A point-in-time copy of the tier's counters, merged into
/// `stream::SessionStats` by the manager.
#[derive(Clone, Copy, Debug, Default)]
pub struct SpillCounters {
    /// background writes committed to the manifest
    pub commits: u64,
    /// queued writes skipped or undone (take-back, close, or a newer
    /// spill of the same id superseded them)
    pub cancels: u64,
    /// background writes that failed (the session is converted to a
    /// loud eviction at the manager's next batch)
    pub write_failures: u64,
    /// cumulative serving-thread time spent enqueueing spills, ns
    pub enqueue_nanos: u64,
    /// cumulative writer-thread time spent writing + committing, ns
    pub write_nanos: u64,
    /// spills currently parked awaiting their background write
    pub pending: u64,
    /// bytes of encoded snapshots currently parked awaiting their
    /// background write (the write-back staging footprint)
    pub pending_bytes: u64,
    /// enqueues refused at the pending-byte high-water mark
    pub sheds: u64,
}

struct Shared {
    ck: Mutex<Checkpointer>,
    pending: Mutex<HashMap<String, PendingSpill>>,
    /// ids with a committed snapshot, mirrored from `ck` so membership
    /// checks on the serving path (`contains`, gauges) never wait on a
    /// manifest fsync the writer is running under the `ck` lock. The
    /// writer inserts here *before* retiring the pending entry, so a
    /// demoted session is never transiently invisible
    committed: Mutex<BTreeSet<String>>,
    /// (id, seq) of spills whose background write failed. The serving
    /// thread reaps these at its next batch and converts them to loud
    /// evictions — the same degradation a failed synchronous spill had —
    /// so parked scorers never accumulate unboundedly behind a bad disk
    failed: Mutex<Vec<(String, u64)>>,
    stats: WriterStats,
    /// serving-thread enqueue time lives here too so `SpillCounters`
    /// can be read from one place
    enqueue_nanos: AtomicU64,
    /// bytes of encoded snapshots currently parked in `pending` —
    /// updated under the pending lock at every insert/remove, read
    /// lock-free by the gauges
    pending_bytes: AtomicU64,
    /// high-water mark on `pending_bytes` (0 = unbounded): an enqueue
    /// that would cross it is refused (shed), bounding the staging
    /// memory a stalled writer can pin
    pending_limit: AtomicU64,
    /// enqueues refused at the high-water mark
    sheds: AtomicU64,
    /// test/ops hook: while true, the writer parks before each job
    gate: (Mutex<bool>, Condvar),
}

impl Shared {
    fn wait_gate(&self) {
        let (lock, cvar) = &self.gate;
        let mut held = lock.lock().expect("spill gate poisoned");
        while *held {
            held = cvar.wait(held).expect("spill gate poisoned");
        }
    }
}

/// The spill tier handle owned by a `SessionManager`: a checkpoint
/// directory, the pending (write-back) map, and the writer thread.
pub struct SpillTier {
    shared: Arc<Shared>,
    tx: Option<Sender<Job>>,
    worker: Option<JoinHandle<()>>,
    next_seq: u64,
}

impl SpillTier {
    /// Open the spill directory (clearing any stale snapshots from a
    /// previous process — the spill tier caches one process's live
    /// sessions, never a dead one's) and start the writer thread.
    pub fn create(dir: &Path) -> Result<SpillTier> {
        let mut ck = Checkpointer::create(dir).context("opening spill directory")?;
        let stale = ck.clear().context("clearing stale spill snapshots")?;
        if stale > 0 {
            eprintln!("[spill] cleared {stale} stale spill snapshot(s) in {}", dir.display());
        }
        let shared = Arc::new(Shared {
            ck: Mutex::new(ck),
            pending: Mutex::new(HashMap::new()),
            committed: Mutex::new(BTreeSet::new()),
            failed: Mutex::new(Vec::new()),
            stats: WriterStats::default(),
            enqueue_nanos: AtomicU64::new(0),
            pending_bytes: AtomicU64::new(0),
            pending_limit: AtomicU64::new(0),
            sheds: AtomicU64::new(0),
            gate: (Mutex::new(false), Condvar::new()),
        });
        let (tx, rx) = channel::<Job>();
        let shared2 = shared.clone();
        let worker = std::thread::Builder::new()
            .name("spill-writer".to_string())
            .spawn(move || writer_loop(&rx, &shared2))?;
        Ok(SpillTier { shared, tx: Some(tx), worker: Some(worker), next_seq: 0 })
    }

    /// The spill directory path.
    pub fn dir(&self) -> PathBuf {
        self.shared.ck.lock().expect("spill checkpointer poisoned").dir().to_path_buf()
    }

    /// Bound the write-back staging footprint: an enqueue that would
    /// push the parked-snapshot bytes past `limit` is refused (shed),
    /// so a stalled writer can pin at most `limit` bytes of encoded
    /// snapshots. 0 (the default) means unbounded.
    pub fn set_pending_limit(&self, limit: usize) {
        self.shared.pending_limit.store(limit as u64, Ordering::Relaxed);
    }

    /// Demote a session: capture + encode its snapshot on the calling
    /// thread (a few tens of kilobytes of memcpy), park the scorer in
    /// the pending map and hand the bytes to the writer. Returns the
    /// encoded snapshot size. On capture failure the session's context
    /// is dropped — the caller falls back to a loud eviction, exactly
    /// as a failed synchronous spill did.
    pub fn enqueue(
        &mut self,
        id: &str,
        scorer: ChunkScorer,
        dirty_gen: u64,
        exporter: u64,
    ) -> Result<u64> {
        let t0 = Instant::now();
        let snap = SessionSnapshot::capture(id, &scorer)?;
        let bytes = snap.to_bytes();
        let size = bytes.len() as u64;
        let pos = scorer.tokens_seen() as u64;
        // staging high-water mark: refuse (shed) an enqueue that would
        // pin more encoded bytes than the limit allows — the caller
        // degrades to a loud eviction, and the bounded-memory contract
        // survives a stalled writer
        let limit = self.shared.pending_limit.load(Ordering::Relaxed);
        if limit > 0 {
            let staged = self.shared.pending_bytes.load(Ordering::Relaxed);
            if staged + size > limit {
                self.shared.sheds.fetch_add(1, Ordering::Relaxed);
                bail!(
                    "spill staging high-water mark: {staged} pending + {size} new > {limit}"
                );
            }
        }
        self.next_seq += 1;
        let seq = self.next_seq;
        {
            let mut pending = self.shared.pending.lock().expect("spill pending map poisoned");
            let old = pending
                .insert(id.to_string(), PendingSpill { scorer, dirty_gen, seq, bytes: size });
            // a superseded same-id entry releases its staged bytes
            let delta = size as i64 - old.map_or(0, |p| p.bytes as i64);
            if delta >= 0 {
                self.shared.pending_bytes.fetch_add(delta as u64, Ordering::Relaxed);
            } else {
                self.shared.pending_bytes.fetch_sub((-delta) as u64, Ordering::Relaxed);
            }
        }
        let job = Job::Write { id: id.to_string(), seq, bytes, pos, exporter, dirty_gen };
        let sent = self.tx.as_ref().is_some_and(|tx| tx.send(job).is_ok());
        if !sent {
            // writer died: un-park the entry and fail the enqueue so the
            // caller degrades to a loud eviction — parking a scorer no
            // one will ever write would leak it past the byte budget
            self.shared.stats.write_failures.fetch_add(1, Ordering::Relaxed);
            let mut pending = self.shared.pending.lock().expect("spill pending map poisoned");
            if let Some(p) = pending.remove(id) {
                self.shared.pending_bytes.fetch_sub(p.bytes, Ordering::Relaxed);
            }
            bail!("spill writer thread is gone");
        }
        self.shared
            .enqueue_nanos
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        Ok(size)
    }

    /// Drain the failed-write list: (id, seq) pairs whose background
    /// write failed since the last call. For each, the caller should
    /// [`Self::drop_failed_pending`] and treat the session as loudly
    /// evicted.
    pub fn take_failed(&self) -> Vec<(String, u64)> {
        std::mem::take(&mut *self.shared.failed.lock().expect("spill failed list poisoned"))
    }

    /// Drop the parked scorer of a failed spill, if it is still the one
    /// that failed (a rehydration may have reclaimed it meanwhile — then
    /// nothing was lost and nothing is dropped). Returns whether the
    /// entry was dropped.
    pub fn drop_failed_pending(&self, id: &str, seq: u64) -> bool {
        let mut pending = self.shared.pending.lock().expect("spill pending map poisoned");
        if pending.get(id).is_some_and(|p| p.seq == seq) {
            if let Some(p) = pending.remove(id) {
                self.shared.pending_bytes.fetch_sub(p.bytes, Ordering::Relaxed);
            }
            true
        } else {
            false
        }
    }

    /// Take back a pending (in-flight) spill's resident copy, canceling
    /// its queued write. Returns the scorer and its dirty generation.
    pub fn take_pending(&self, id: &str) -> Option<(ChunkScorer, u64)> {
        self.shared
            .pending
            .lock()
            .expect("spill pending map poisoned")
            .remove(id)
            .map(|p| {
                self.shared.pending_bytes.fetch_sub(p.bytes, Ordering::Relaxed);
                (p.scorer, p.dirty_gen)
            })
    }

    /// Whether `id` is demoted to this tier — parked awaiting its write
    /// or already committed on disk. Never waits on snapshot/manifest
    /// IO: membership reads the mirrored id set, not the checkpointer.
    pub fn contains(&self, id: &str) -> bool {
        if self.shared.pending.lock().expect("spill pending map poisoned").contains_key(id) {
            return true;
        }
        self.shared.committed.lock().expect("spill committed set poisoned").contains(id)
    }

    /// Number of spills parked awaiting their background write.
    pub fn pending_count(&self) -> usize {
        self.shared.pending.lock().expect("spill pending map poisoned").len()
    }

    /// Number of spills committed on disk.
    pub fn committed_count(&self) -> usize {
        self.shared.committed.lock().expect("spill committed set poisoned").len()
    }

    /// Ids parked in the pending map, sorted.
    pub fn pending_ids(&self) -> Vec<String> {
        let mut ids: Vec<String> = self
            .shared
            .pending
            .lock()
            .expect("spill pending map poisoned")
            .keys()
            .cloned()
            .collect();
        ids.sort();
        ids
    }

    /// Ids with a committed snapshot on disk, sorted.
    pub fn committed_ids(&self) -> Vec<String> {
        self.shared
            .committed
            .lock()
            .expect("spill committed set poisoned")
            .iter()
            .cloned()
            .collect()
    }

    /// The committed manifest record for `id`, if one exists.
    pub fn committed_record(&self, id: &str) -> Option<SnapshotRecord> {
        self.shared.ck.lock().expect("spill checkpointer poisoned").record(id).cloned()
    }

    /// Capture an encoded snapshot of every *pending* spill (for
    /// exports: in-flight spills are live sessions too). The callback
    /// runs under the pending lock; entries are visited in sorted-id
    /// order for deterministic exports.
    pub fn for_each_pending(
        &self,
        mut f: impl FnMut(&str, &[u8], u64, u64) -> Result<()>,
    ) -> Result<()> {
        let pending = self.shared.pending.lock().expect("spill pending map poisoned");
        let mut ids: Vec<&String> = pending.keys().collect();
        ids.sort();
        for id in ids {
            let p = &pending[id.as_str()];
            let bytes = SessionSnapshot::capture(id, &p.scorer)?.to_bytes();
            f(id, &bytes, p.scorer.tokens_seen() as u64, p.dirty_gen)?;
        }
        Ok(())
    }

    /// Rehydrate a *committed* spill, consuming its snapshot (the
    /// returned scorer owns the stream from here on). Returns the
    /// scorer and the dirty generation recorded at spill time.
    pub fn load_committed(
        &self,
        id: &str,
        model: &Arc<NativeModel>,
    ) -> Result<(ChunkScorer, u64)> {
        let mut ck = self.shared.ck.lock().expect("spill checkpointer poisoned");
        let dirty_gen = ck.record(id).map(|r| r.dirty_gen).unwrap_or(0);
        let scorer = ck.load(id, model)?;
        ck.remove(id)?;
        self.shared.committed.lock().expect("spill committed set poisoned").remove(id);
        Ok((scorer, dirty_gen))
    }

    /// Drop a session from the tier — cancel a pending spill and/or
    /// remove a committed snapshot. Returns whether anything existed.
    pub fn remove(&self, id: &str) -> Result<bool> {
        let pending = {
            match self.shared.pending.lock().expect("spill pending map poisoned").remove(id) {
                Some(p) => {
                    self.shared.pending_bytes.fetch_sub(p.bytes, Ordering::Relaxed);
                    true
                }
                None => false,
            }
        };
        let committed =
            self.shared.ck.lock().expect("spill checkpointer poisoned").remove(id)?;
        self.shared.committed.lock().expect("spill committed set poisoned").remove(id);
        Ok(pending || committed)
    }

    /// Block until every spill enqueued so far has been written (or
    /// canceled) — the shutdown/test barrier. Fails if the writer died.
    pub fn flush(&self) -> Result<()> {
        let tx = self.tx.as_ref().ok_or_else(|| anyhow!("spill writer already shut down"))?;
        let (ack_tx, ack_rx) = channel();
        tx.send(Job::Flush(ack_tx)).map_err(|_| anyhow!("spill writer is gone"))?;
        ack_rx.recv().map_err(|_| anyhow!("spill writer died mid-flush"))
    }

    /// Test/ops hook: while held, the writer parks before each job, so
    /// spills stay observably in-flight. Release wakes it.
    pub fn hold_writes(&self, on: bool) {
        let (lock, cvar) = &self.shared.gate;
        *lock.lock().expect("spill gate poisoned") = on;
        cvar.notify_all();
    }

    /// Point-in-time counters for metrics.
    pub fn counters(&self) -> SpillCounters {
        SpillCounters {
            commits: self.shared.stats.commits.load(Ordering::Relaxed),
            cancels: self.shared.stats.cancels.load(Ordering::Relaxed),
            write_failures: self.shared.stats.write_failures.load(Ordering::Relaxed),
            enqueue_nanos: self.shared.enqueue_nanos.load(Ordering::Relaxed),
            write_nanos: self.shared.stats.write_nanos.load(Ordering::Relaxed),
            pending: self.pending_count() as u64,
            pending_bytes: self.shared.pending_bytes.load(Ordering::Relaxed),
            sheds: self.shared.sheds.load(Ordering::Relaxed),
        }
    }
}

impl Drop for SpillTier {
    fn drop(&mut self) {
        // release a held gate so the drain cannot deadlock, close the
        // channel, and wait for the writer to finish every queued job
        self.hold_writes(false);
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn writer_loop(rx: &Receiver<Job>, shared: &Shared) {
    while let Ok(job) = rx.recv() {
        match job {
            Job::Flush(ack) => {
                let _ = ack.send(());
            }
            Job::Write { id, seq, bytes, pos, exporter, dirty_gen } => {
                shared.wait_gate();
                let _span = trace::span_n("spill_write", bytes.len() as u64);
                // superseded, taken back or closed before we got here:
                // skip the write entirely
                let live = shared
                    .pending
                    .lock()
                    .expect("spill pending map poisoned")
                    .get(&id)
                    .is_some_and(|p| p.seq == seq);
                if !live {
                    shared.stats.cancels.fetch_add(1, Ordering::Relaxed);
                    continue;
                }
                let t0 = Instant::now();
                let file = snapshot_filename(&id);
                let path = {
                    shared.ck.lock().expect("spill checkpointer poisoned").dir().join(&file)
                };
                // the file write holds no lock: rehydrations and metric
                // reads proceed while the fsync runs
                if let Err(e) = super::checkpointer::write_atomic(&path, &bytes) {
                    eprintln!(
                        "[spill] background write for '{id}' failed ({e:#}); \
                         the session will be evicted loudly"
                    );
                    shared.stats.write_failures.fetch_add(1, Ordering::Relaxed);
                    shared
                        .failed
                        .lock()
                        .expect("spill failed list poisoned")
                        .push((id, seq));
                    continue;
                }
                // PUBLISH atomically with respect to the serving thread:
                // while holding the pending lock (so no take-back or
                // close can interleave), verify the entry still expects
                // this job, insert the in-memory record + the committed
                // mirror, and retire the entry. A session closed or
                // taken back after the pre-check is therefore never
                // published — its stale snapshot can never resurrect —
                // and a published session is loadable before the entry
                // disappears, so it is never transiently invisible. The
                // locks guard only in-memory maps here; the manifest
                // fsync happens after, outside the pending lock.
                let published = {
                    let mut pending =
                        shared.pending.lock().expect("spill pending map poisoned");
                    if pending.get(&id).is_some_and(|p| p.seq == seq) {
                        let record = SnapshotRecord {
                            id: id.clone(),
                            file,
                            bytes: bytes.len() as u64,
                            crc: crc32(&bytes),
                            pos,
                            exporter,
                            dirty_gen,
                        };
                        shared
                            .ck
                            .lock()
                            .expect("spill checkpointer poisoned")
                            .stage_record(record);
                        shared
                            .committed
                            .lock()
                            .expect("spill committed set poisoned")
                            .insert(id.clone());
                        if let Some(p) = pending.remove(&id) {
                            shared.pending_bytes.fetch_sub(p.bytes, Ordering::Relaxed);
                        }
                        true
                    } else {
                        false
                    }
                };
                if published {
                    // persist the manifest for out-of-process readers;
                    // in-memory state is already consistent, and the
                    // spill dir is a per-process cache, so a failure
                    // here only costs durability of this one manifest
                    // write (logged, not fatal)
                    if let Err(e) =
                        shared.ck.lock().expect("spill checkpointer poisoned").commit()
                    {
                        eprintln!("[spill] manifest write for a spill failed: {e:#}");
                    }
                    shared.stats.commits.fetch_add(1, Ordering::Relaxed);
                } else {
                    // canceled between pre-check and publish: the file
                    // we wrote is an unreferenced orphan — reclaim it
                    shared.stats.cancels.fetch_add(1, Ordering::Relaxed);
                    let _ = std::fs::remove_file(&path);
                }
                shared
                    .stats
                    .write_nanos
                    .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protein::vocab::{AA_BASE, N_AA};
    use crate::rng::Pcg64;
    use crate::train::SyntheticConfig;

    fn model() -> Arc<NativeModel> {
        let mut rng = Pcg64::new(41);
        Arc::new(NativeModel::synthetic(&SyntheticConfig::default(), &mut rng))
    }

    fn tokens(n: usize, seed: u64) -> Vec<u8> {
        let mut rng = Pcg64::new(seed);
        (0..n).map(|_| AA_BASE + rng.below(N_AA) as u8).collect()
    }

    fn tempdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("pfrm_spill_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn enqueue_commits_in_background_and_flush_waits() {
        let dir = tempdir("commit");
        let m = model();
        let mut tier = SpillTier::create(&dir).unwrap();
        let mut scorer = ChunkScorer::new(m.clone()).unwrap();
        scorer.advance(&tokens(16, 1)).unwrap();
        let size = tier.enqueue("a", scorer, 3, 42).unwrap();
        assert!(size > 0);
        assert!(tier.contains("a"), "pending spill is part of the tier");
        tier.flush().unwrap();
        assert_eq!(tier.pending_count(), 0, "flush drains the queue");
        assert_eq!(tier.committed_count(), 1);
        let rec = tier.committed_record("a").unwrap();
        assert_eq!((rec.exporter, rec.dirty_gen, rec.pos), (42, 3, 16));
        let c = tier.counters();
        assert_eq!((c.commits, c.cancels, c.write_failures), (1, 0, 0));
        assert!(c.enqueue_nanos > 0 && c.write_nanos > 0);

        let (restored, dirty) = tier.load_committed("a", &m).unwrap();
        assert_eq!((restored.tokens_seen(), dirty), (16, 3));
        assert!(!tier.contains("a"), "load_committed consumes the snapshot");
        drop(tier);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn take_pending_cancels_the_queued_write() {
        let dir = tempdir("cancel");
        let m = model();
        let mut tier = SpillTier::create(&dir).unwrap();
        tier.hold_writes(true);
        let mut scorer = ChunkScorer::new(m).unwrap();
        scorer.advance(&tokens(16, 2)).unwrap();
        tier.enqueue("a", scorer, 5, 9).unwrap();
        // take the resident copy back while the write is held in flight
        let (scorer, dirty) = tier.take_pending("a").expect("pending copy available");
        assert_eq!((scorer.tokens_seen(), dirty), (16, 5));
        tier.hold_writes(false);
        tier.flush().unwrap();
        assert_eq!(tier.committed_count(), 0, "canceled write must not commit");
        let c = tier.counters();
        assert_eq!((c.commits, c.cancels), (0, 1));
        drop(tier);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn drop_drains_queued_writes() {
        let dir = tempdir("drain");
        let m = model();
        {
            let mut tier = SpillTier::create(&dir).unwrap();
            for (i, id) in ["a", "b", "c"].iter().enumerate() {
                let mut scorer = ChunkScorer::new(m.clone()).unwrap();
                scorer.advance(&tokens(8, 10 + i as u64)).unwrap();
                tier.enqueue(id, scorer, i as u64, 1).unwrap();
            }
        } // drop: shutdown must drain all three writes
        let ck = Checkpointer::open(&dir).unwrap();
        assert_eq!(ck.ids(), vec!["a".to_string(), "b".into(), "c".into()]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn pending_limit_sheds_and_accounts_bytes_exactly() {
        let dir = tempdir("hwm");
        let m = model();
        let mut tier = SpillTier::create(&dir).unwrap();
        tier.hold_writes(true);

        // first spill fits under a mark sized for exactly one snapshot
        let mut a = ChunkScorer::new(m.clone()).unwrap();
        a.advance(&tokens(16, 30)).unwrap();
        let size_a = tier.enqueue("a", a, 1, 7).unwrap();
        tier.set_pending_limit(size_a as usize);
        let c = tier.counters();
        assert_eq!((c.pending, c.pending_bytes, c.sheds), (1, size_a, 0));

        // the second would cross the mark: shed, nothing parked
        let mut b = ChunkScorer::new(m).unwrap();
        b.advance(&tokens(16, 31)).unwrap();
        let err = tier.enqueue("b", b, 2, 7).unwrap_err();
        assert!(format!("{err:#}").contains("high-water mark"), "{err:#}");
        let c = tier.counters();
        assert_eq!((c.pending, c.pending_bytes, c.sheds), (1, size_a, 1));
        assert!(!tier.contains("b"));

        // draining the writer releases the staged bytes to exactly zero
        tier.hold_writes(false);
        tier.flush().unwrap();
        let c = tier.counters();
        assert_eq!((c.pending, c.pending_bytes), (0, 0));
        assert_eq!(c.commits, 1);
        assert!(tier.contains("a"), "the spill that fit still committed");
        drop(tier);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn startup_clears_stale_snapshots() {
        let dir = tempdir("stale");
        let m = model();
        {
            let mut tier = SpillTier::create(&dir).unwrap();
            let mut scorer = ChunkScorer::new(m).unwrap();
            scorer.advance(&tokens(8, 20)).unwrap();
            tier.enqueue("old", scorer, 1, 1).unwrap();
            tier.flush().unwrap();
        }
        let tier = SpillTier::create(&dir).unwrap();
        assert!(!tier.contains("old"), "a fresh tier must not resurrect old spills");
        drop(tier);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
