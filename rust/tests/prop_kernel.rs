//! Property tests of the pluggable attention-kernel layer (same
//! seeded-generator harness as `prop_favor.rs` — rerun any failure with
//! the printed seed):
//!
//!   * FAVOR+ positive features never produce a non-positive attention
//!     normalizer D, even on adversarially scaled inputs;
//!   * FAVOR+ approximates exact softmax attention within the same
//!     tolerance envelope as trig features at equal M, in both
//!     directions;
//!   * the kernel handle is a zero-cost seam: `favor_attention` through
//!     an `AttentionKernel` is bitwise-identical to the raw epoch-0
//!     `FeatureMap`, and the in-place fused phi equals the copy-and-apply
//!     path bit for bit;
//!   * the clamped `exp` generalized-attention kernel survives
//!     adversarial projections (regression: unguarded exp overflowed to
//!     inf and poisoned whole rows);
//!   * FAVOR+ streams: chunked `StreamState::advance` over random splits
//!     equals the single-shot estimator.

use performer::favor::linear::{favor_unidirectional, row_mass};
use performer::favor::{
    exact_attention, favor_attention, AttentionKernel, Direction, FeatureKind, FeatureMap,
    KernelConfig,
};
use performer::linalg::OrfMechanism;
use performer::rng::Pcg64;
use performer::stream::StreamState;
use performer::tensor::Mat;

const CASES: u64 = 25;

/// Tiny property-test harness: runs `f` across seeded cases, panics with
/// the failing seed for reproduction.
fn forall(name: &str, f: impl Fn(&mut Pcg64)) {
    for seed in 0..CASES {
        let mut rng = Pcg64::new(0xfeed ^ seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            panic!("property '{name}' failed at seed {seed}: {e:?}");
        }
    }
}

fn rand_mat(rng: &mut Pcg64, r: usize, c: usize, scale: f32) -> Mat {
    Mat::from_vec(r, c, rng.gaussian_vec(r * c).iter().map(|v| v * scale).collect())
}

#[test]
fn prop_positive_normalizer_never_nonpositive() {
    forall("FAVOR+ normalizer D > 0", |rng| {
        let l = 8 + rng.below(24);
        let d = [4usize, 8, 16][rng.below(3)];
        let m = [8usize, 16, 32][rng.below(3)];
        // adversarial scales included: huge activations once overflowed
        // unstabilized positive features
        let scale = [0.5f32, 2.0, 50.0, 500.0][rng.below(4)];
        let fm = FeatureMap::sample(FeatureKind::Positive, m, d, OrfMechanism::Regular, rng);
        let qp = fm.apply(&rand_mat(rng, l, d, scale));
        let kp = fm.apply(&rand_mat(rng, l, d, scale));
        assert!(qp.data.iter().chain(&kp.data).all(|v| v.is_finite() && *v > 0.0));
        for (i, mass) in row_mass(&qp, &kp).iter().enumerate() {
            assert!(
                mass.is_finite() && *mass > 0.0,
                "row {i}: normalizer mass {mass} must be strictly positive (scale {scale})"
            );
        }
    });
}

#[test]
fn positive_matches_exact_attention_within_trig_envelope() {
    // the satellite contract: FAVOR+ at equal M lands inside the same
    // tolerance envelope the trig estimator is pinned to (0.05 bid /
    // 0.08 uni in favor::linear's tests)
    let (l, d, m) = (24usize, 8usize, 1024usize);
    for (dir, tol, seed) in [
        (Direction::Bidirectional, 0.05f64, 61u64),
        (Direction::Unidirectional, 0.08, 62),
    ] {
        let mut rng = Pcg64::new(seed);
        let q = rand_mat(&mut rng, l, d, 0.4);
        let k = rand_mat(&mut rng, l, d, 0.4);
        let v = rand_mat(&mut rng, l, d, 1.0);
        let exact = exact_attention(&q, &k, &v, dir);
        let pos = FeatureMap::sample(FeatureKind::Positive, m, d, OrfMechanism::Regular, &mut rng);
        let err_pos = exact.mean_abs_diff(&favor_attention(&pos, &q, &k, &v, dir));
        assert!(err_pos < tol, "{dir:?}: FAVOR+ error {err_pos} exceeds the {tol} envelope");

        // and it should not be wildly worse than trig on the same draw
        // budget (positive features exist to *reduce* variance)
        let trig = FeatureMap::sample(FeatureKind::Softmax, m, d, OrfMechanism::Regular, &mut rng);
        let err_trig = exact.mean_abs_diff(&favor_attention(&trig, &q, &k, &v, dir));
        assert!(
            err_pos < err_trig * 3.0 + 1e-3,
            "{dir:?}: FAVOR+ {err_pos} should be comparable to trig {err_trig}"
        );
    }
}

#[test]
fn kernel_handle_is_bitwise_transparent() {
    forall("favor_attention(kernel) == favor_attention(feature_map)", |rng| {
        let l = 8 + rng.below(16);
        let d = [4usize, 8][rng.below(2)];
        let kind = [FeatureKind::Relu, FeatureKind::Positive, FeatureKind::Softmax]
            [rng.below(3)];
        let kernel = AttentionKernel::new(
            KernelConfig { kind, m: 16, seed: rng.next_u64(), ..Default::default() },
            d,
        );
        let q = rand_mat(rng, l, d, 0.5);
        let k = rand_mat(rng, l, d, 0.5);
        let v = rand_mat(rng, l, d, 1.0);
        for dir in [Direction::Bidirectional, Direction::Unidirectional] {
            let via_kernel = favor_attention(&kernel, &q, &k, &v, dir);
            let via_map = favor_attention(kernel.feature_map(), &q, &k, &v, dir);
            assert_eq!(via_kernel.data, via_map.data, "{kind:?} {dir:?}");
        }
    });
}

#[test]
fn prop_fused_phi_block_equals_copied_block() {
    forall("apply_block == apply(copied slice)", |rng| {
        let rows = 6 + rng.below(10);
        let d = [4usize, 8][rng.below(2)];
        let width = 3 * d; // a QKV-like stack
        let col_lo = d * rng.below(3);
        let kind = [FeatureKind::Relu, FeatureKind::Positive, FeatureKind::Softmax, FeatureKind::Exp]
            [rng.below(4)];
        let fm = FeatureMap::sample(kind, 12, d, OrfMechanism::Regular, rng);
        let x = rand_mat(rng, rows, width, 0.8);
        let lo = rng.below(rows / 2);
        let hi = lo + 1 + rng.below(rows - lo - 1);
        let blk = fm.apply_block(&x, lo, hi, col_lo);
        let copied = Mat::from_fn(hi - lo, d, |i, j| x.at(lo + i, col_lo + j));
        assert_eq!(blk.data, fm.apply(&copied).data, "{kind:?}");
    });
}

#[test]
fn exp_kernel_survives_adversarial_inputs_end_to_end() {
    // regression for the unguarded exp overflow: run the whole linear
    // attention, not just the feature map
    let mut rng = Pcg64::new(77);
    let (l, d, m) = (16usize, 8usize, 16usize);
    let fm = FeatureMap::sample(FeatureKind::Exp, m, d, OrfMechanism::Regular, &mut rng);
    for scale in [1.0f32, 30.0, 300.0, 3000.0] {
        let q = rand_mat(&mut rng, l, d, scale);
        let k = rand_mat(&mut rng, l, d, scale);
        let v = rand_mat(&mut rng, l, d, 1.0);
        for dir in [Direction::Bidirectional, Direction::Unidirectional] {
            let out = favor_attention(&fm, &q, &k, &v, dir);
            assert!(
                out.data.iter().all(|x| x.is_finite()),
                "scale {scale} {dir:?}: exp kernel output went non-finite"
            );
        }
    }
}

#[test]
fn prop_positive_features_stream_chunked_equals_single_shot() {
    forall("FAVOR+ chunked == single shot", |rng| {
        let l = 16 + rng.below(48);
        let (d, m) = (8usize, 16usize);
        let fm = FeatureMap::sample(FeatureKind::Positive, m, d, OrfMechanism::Regular, rng);
        let qp = fm.apply(&rand_mat(rng, l, d, 0.5));
        let kp = fm.apply(&rand_mat(rng, l, d, 0.5));
        let v = rand_mat(rng, l, d, 1.0);
        let single = favor_unidirectional(&qp, &kp, &v);

        let mut st = StreamState::new(m, d);
        let mut rows = Vec::with_capacity(l * d);
        let mut lo = 0;
        while lo < l {
            let hi = (lo + 1 + rng.below(11)).min(l);
            rows.extend(st.advance(&qp.rows_slice(lo, hi), &kp.rows_slice(lo, hi), &v.rows_slice(lo, hi)).data);
            lo = hi;
        }
        let streamed = Mat::from_vec(l, d, rows);
        let diff = streamed.max_abs_diff(&single);
        assert!(diff < 1e-6, "FAVOR+ chunked stream diverges by {diff}");
    });
}
