//! Property tests for the reduced-precision (bf16) stream state.
//!
//! The contract being pinned (see DESIGN.md §Dense-core SIMD + reduced
//! precision): bf16 is a *storage* format — every advance dequantizes to
//! f32 scratch, runs the exact f32 recurrence, and requantizes once per
//! chunk boundary. So:
//!
//!   * bf16 vs f32 scores stay inside a documented envelope
//!     (max |Δ logprob| < 0.5 nats, mean < 0.1) across random
//!     chunkings and kernel-redraw epochs;
//!   * *within* the bf16 mode everything stays bitwise: spill →
//!     rehydrate → advance equals an uninterrupted bf16 session, and a
//!     snapshot round-trip resumes bit-for-bit;
//!   * a bf16 manager refuses f32 checkpoints and vice versa (the
//!     fingerprint embeds the precision; the manager enforces policy);
//!   * bf16 halves the per-session resident bytes reported by stats.

use std::path::PathBuf;
use std::sync::Arc;

use performer::persist::SessionSnapshot;
use performer::protein::vocab::{AA_BASE, N_AA};
use performer::rng::Pcg64;
use performer::stream::{
    ChunkScorer, ChunkScores, SessionConfig, SessionManager, StatePrecision,
};
use performer::train::{NativeModel, SyntheticConfig};

const CASES: u64 = 10;

/// Same seeded-case harness as prop_stream/prop_persist: rerun any
/// failure with the printed seed.
fn forall(name: &str, f: impl Fn(&mut Pcg64)) {
    for seed in 0..CASES {
        let mut rng = Pcg64::new(0xbf16 ^ seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            panic!("property '{name}' failed at seed {seed}: {e:?}");
        }
    }
}

fn aa_tokens(rng: &mut Pcg64, n: usize) -> Vec<u8> {
    (0..n).map(|_| AA_BASE + rng.below(N_AA) as u8).collect()
}

fn tempdir(tag: &str, seed: u64) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("pfrm_quant_{tag}_{seed}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn bits(s: &ChunkScores) -> Vec<u32> {
    s.logprob.iter().map(|v| v.to_bits()).collect()
}

fn bf16_cfg() -> SessionConfig {
    SessionConfig { precision: StatePrecision::Bf16, ..Default::default() }
}

/// A model with a live redraw schedule, so the sweeps cross kernel
/// epochs (state resets + reaccumulation under fresh features).
fn redraw_model(seed: u64) -> Arc<NativeModel> {
    let mut rng = Pcg64::new(seed);
    Arc::new(NativeModel::synthetic(
        &SyntheticConfig { redraw_every: 48, ..Default::default() },
        &mut rng,
    ))
}

#[test]
fn prop_bf16_scores_track_f32_inside_the_envelope() {
    let model = redraw_model(8101);
    forall("bf16 vs f32 logprobs inside envelope", |rng| {
        let mut exact = SessionManager::new(model.clone(), SessionConfig::default()).unwrap();
        let mut quant = SessionManager::new(model.clone(), bf16_cfg()).unwrap();
        let mut worst = 0.0f32;
        let mut sum = 0.0f64;
        let mut count = 0usize;
        // random chunkings, long enough to cross several redraw epochs
        for _ in 0..6 {
            let chunk = aa_tokens(rng, 8 + rng.below(40));
            let a = exact.advance("u", &chunk).unwrap();
            let b = quant.advance("u", &chunk).unwrap();
            assert_eq!(a.offset, b.offset);
            for (x, y) in a.logprob.iter().zip(&b.logprob) {
                let d = (x - y).abs();
                worst = worst.max(d);
                sum += d as f64;
                count += 1;
            }
        }
        let mean = sum / count.max(1) as f64;
        assert!(worst < 0.5, "max |Δ logprob| {worst} outside the 0.5-nat envelope");
        assert!(mean < 0.1, "mean |Δ logprob| {mean} outside the 0.1-nat envelope");
    });
}

#[test]
fn prop_bf16_spill_rehydrate_is_bitwise_transparent() {
    let model = redraw_model(8102);
    let per = SessionManager::new(model.clone(), bf16_cfg()).unwrap().per_session_bytes();
    forall("bf16 spill -> rehydrate == uninterrupted bf16", |rng| {
        let seed_tag = rng.below(1 << 30) as u64;
        let dir = tempdir("spill", seed_tag);
        // one-session budget: every session switch forces a spill
        let cfg = SessionConfig {
            max_state_bytes: per,
            max_sessions: 0,
            spill_dir: Some(dir.clone()),
            spill_pending_limit: 0,
            precision: StatePrecision::Bf16,
            ..Default::default()
        };
        let mut spilling = SessionManager::new(model.clone(), cfg).unwrap();
        let mut reference = SessionManager::new(model.clone(), bf16_cfg()).unwrap();
        for _ in 0..3 {
            for s in 0..2 {
                let chunk = aa_tokens(rng, 1 + rng.below(32));
                let id = format!("u{s}");
                let a = spilling.advance(&id, &chunk).unwrap();
                let b = reference.advance(&id, &chunk).unwrap();
                assert_eq!(
                    bits(&a),
                    bits(&b),
                    "session {id}: bf16 spilled path diverged from uninterrupted"
                );
            }
        }
        spilling.sync_spills().unwrap();
        let st = spilling.stats();
        assert!(st.spills > 0, "the schedule must actually force spills");
        assert_eq!(st.spill_write_failures, 0);
        let _ = std::fs::remove_dir_all(&dir);
    });
}

#[test]
fn prop_bf16_snapshot_roundtrip_resumes_bitwise() {
    let model = redraw_model(8103);
    forall("bf16 snapshot -> bytes -> scorer resumes exactly", |rng| {
        let mut scorer =
            ChunkScorer::new_with_precision(model.clone(), StatePrecision::Bf16).unwrap();
        for _ in 0..1 + rng.below(3) {
            scorer.advance(&aa_tokens(rng, 8 + rng.below(40))).unwrap();
        }
        let snap = SessionSnapshot::capture("q", &scorer).unwrap();
        assert_eq!(snap.precision(), StatePrecision::Bf16);
        let mut restored = SessionSnapshot::from_bytes(&snap.to_bytes())
            .unwrap()
            .into_scorer(model.clone())
            .unwrap();
        assert_eq!(restored.precision(), StatePrecision::Bf16);
        let next = aa_tokens(rng, 1 + rng.below(24));
        assert_eq!(
            bits(&scorer.advance(&next).unwrap()),
            bits(&restored.advance(&next).unwrap()),
            "bf16 snapshot round-trip must resume bit-for-bit"
        );
    });
}

#[test]
fn cross_precision_restore_is_refused_both_ways() {
    let model = redraw_model(8104);
    let mut rng = Pcg64::new(3);
    for (donor_p, taker_p) in
        [(StatePrecision::F32, StatePrecision::Bf16), (StatePrecision::Bf16, StatePrecision::F32)]
    {
        let dir = tempdir("xprec", donor_p.bytes_per_entry() as u64);
        let donor_cfg = SessionConfig { precision: donor_p, ..Default::default() };
        let mut donor = SessionManager::new(model.clone(), donor_cfg).unwrap();
        donor.advance("a", &aa_tokens(&mut rng, 16)).unwrap();
        donor.checkpoint_all(&dir).unwrap();

        let taker_cfg = SessionConfig { precision: taker_p, ..Default::default() };
        let mut taker = SessionManager::new(model.clone(), taker_cfg).unwrap();
        let err = taker.restore_from(&dir).unwrap_err().to_string();
        assert!(
            err.contains(donor_p.name()) && err.contains(taker_p.name()),
            "refusal must name both precisions, got: {err}"
        );
        assert!(taker.is_empty(), "a refused restore must adopt nothing");

        // same-precision restore of the same checkpoint works
        let ok_cfg = SessionConfig { precision: donor_p, ..Default::default() };
        let mut ok = SessionManager::new(model.clone(), ok_cfg).unwrap();
        assert_eq!(ok.restore_from(&dir).unwrap(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn bf16_halves_per_session_resident_bytes() {
    let model = redraw_model(8105);
    let mut rng = Pcg64::new(4);
    let mut exact = SessionManager::new(model.clone(), SessionConfig::default()).unwrap();
    let mut quant = SessionManager::new(model.clone(), bf16_cfg()).unwrap();
    assert_eq!(
        2 * quant.per_session_bytes(),
        exact.per_session_bytes(),
        "bf16 prefix sums must cost exactly half the f32 bytes"
    );
    let chunk = aa_tokens(&mut rng, 24);
    exact.advance("u", &chunk).unwrap();
    quant.advance("u", &chunk).unwrap();
    let (se, sq) = (exact.stats(), quant.stats());
    assert_eq!(2 * sq.per_session_bytes, se.per_session_bytes);
    assert_eq!(2 * sq.resident_bytes, se.resident_bytes);
}
