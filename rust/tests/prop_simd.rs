//! Property tests for the SIMD dense-core kernels: every vector level a
//! build + host supports is compared against the serial oracle.
//!
//! Oracle discipline (mirrors `tensor::simd`'s module docs):
//!   * `axpy` (and everything built on it — all matmul paths) is
//!     **bitwise** identical across levels: mul + add per lane, never
//!     FMA, k-order preserved;
//!   * `dot` and the softmax sum re-associate the reduction, so they get
//!     a **tolerance** oracle;
//!   * the vector exp is a polynomial, not libm, so `fused_exp_scale`
//!     and the softmax exponentials get a tolerance oracle too.
//!
//! The process-global dispatch override is mutated by exactly one test
//! (`global_override_round_trip_and_matmul_paths`) — every other test
//! uses the explicit-level `_at` entry points, which never read the
//! global, so the default parallel test runner is race-free. This lives
//! in its own integration binary (not the lib tests) for the same
//! reason: lib tests pin bitwise behaviour at the active level and must
//! not observe a transient override from a sibling thread.

use performer::rng::Pcg64;
use performer::tensor::simd::{
    self, axpy_at, dot_at, fused_exp_scale_at, softmax_row_at, supported_levels,
};
use performer::tensor::{
    active_level, matmul_at_b, matmul_block, matmul_rows_tiled, set_level_override, Mat,
    SimdLevel,
};

/// Lengths that exercise every tail path: empty, sub-lane, one SSE2/NEON
/// lane ± 1, one AVX2 lane ± 1, several lanes + ragged tail.
const LENS: &[usize] = &[0, 1, 3, 4, 5, 7, 8, 9, 15, 16, 17, 31, 32, 33, 100, 257];

fn gauss(rng: &mut Pcg64, n: usize) -> Vec<f32> {
    rng.gaussian_vec(n)
}

#[test]
fn axpy_is_bitwise_identical_across_levels() {
    let mut rng = Pcg64::new(11);
    for &n in LENS {
        let x = gauss(&mut rng, n);
        let y0 = gauss(&mut rng, n);
        let alpha = rng.gaussian() as f32;
        let mut want = y0.clone();
        axpy_at(SimdLevel::Scalar, alpha, &x, &mut want);
        for level in supported_levels() {
            let mut got = y0.clone();
            axpy_at(level, alpha, &x, &mut got);
            for (i, (w, g)) in want.iter().zip(&got).enumerate() {
                assert_eq!(
                    w.to_bits(),
                    g.to_bits(),
                    "axpy n={n} level={} lane {i}: {w} vs {g}",
                    level.name()
                );
            }
        }
    }
}

#[test]
fn dot_matches_serial_within_reduction_tolerance() {
    let mut rng = Pcg64::new(12);
    for &n in LENS {
        let a = gauss(&mut rng, n);
        let b = gauss(&mut rng, n);
        let want = dot_at(SimdLevel::Scalar, &a, &b);
        // re-associated sum: error scales with the absolute-value mass
        let mass: f32 = a.iter().zip(&b).map(|(x, y)| (x * y).abs()).sum();
        let tol = 1e-6 * mass.max(1.0);
        for level in supported_levels() {
            let got = dot_at(level, &a, &b);
            assert!(
                (want - got).abs() <= tol,
                "dot n={n} level={}: {want} vs {got} (tol {tol})",
                level.name()
            );
        }
    }
}

#[test]
fn fused_exp_scale_matches_libm_oracle() {
    let mut rng = Pcg64::new(13);
    for &n in LENS {
        // spread values across the interesting range incl. the clamp edge
        let base: Vec<f32> =
            (0..n).map(|_| rng.uniform_in(-30.0, 12.0) as f32).collect();
        let (sub, clamp, scale, eps) = (1.5f32, 8.0f32, 0.37f32, 1e-6f32);
        let mut want = base.clone();
        fused_exp_scale_at(SimdLevel::Scalar, &mut want, sub, clamp, scale, eps);
        for level in supported_levels() {
            let mut got = base.clone();
            fused_exp_scale_at(level, &mut got, sub, clamp, scale, eps);
            for (i, (w, g)) in want.iter().zip(&got).enumerate() {
                let tol = 2e-6 * w.abs().max(1e-6);
                assert!(
                    (w - g).abs() <= tol,
                    "fused_exp n={n} level={} lane {i}: {w} vs {g}",
                    level.name()
                );
            }
        }
    }
}

#[test]
fn softmax_rows_normalize_and_match_serial() {
    let mut rng = Pcg64::new(14);
    for &n in LENS {
        if n == 0 {
            continue;
        }
        let base = gauss(&mut rng, n);
        let mut want = base.clone();
        softmax_row_at(SimdLevel::Scalar, &mut want);
        for level in supported_levels() {
            let mut got = base.clone();
            softmax_row_at(level, &mut got);
            let sum: f32 = got.iter().sum();
            assert!((sum - 1.0).abs() < 1e-4, "softmax n={n} sums to {sum}");
            for (w, g) in want.iter().zip(&got) {
                assert!(
                    (w - g).abs() <= 1e-5,
                    "softmax n={n} level={}: {w} vs {g}",
                    level.name()
                );
            }
        }
    }
}

/// The one test allowed to touch the process-global dispatch override.
/// Covers the override round trip (set / unsupported fallback / clear)
/// and, while each level is pinned, re-runs the matmul entry points —
/// which read the global internally — against the scalar-pinned result.
/// All matmul paths are axpy-based with preserved k-order, so they must
/// be **bitwise** identical across levels and tile choices.
#[test]
fn global_override_round_trip_and_matmul_paths() {
    let detected = set_level_override(None);
    assert_eq!(active_level(), detected);

    // scalar pin always holds
    assert_eq!(set_level_override(Some(SimdLevel::Scalar)), SimdLevel::Scalar);

    // an unsupported request falls back to the detected level
    let foreign = if cfg!(target_arch = "x86_64") { SimdLevel::Neon } else { SimdLevel::Avx2 };
    if !simd::supported(foreign) {
        assert_eq!(set_level_override(Some(foreign)), detected);
    }

    // matmul bitwise invariance: pin scalar for the oracle, then compare
    // every supported level and several depth tiles against it
    let (m, k, n) = (13, 37, 9);
    let mut rng = Pcg64::new(15);
    let a = Mat::from_vec(m, k, rng.gaussian_vec(m * k));
    let b = Mat::from_vec(k, n, rng.gaussian_vec(k * n));
    // same row count as `a`, for the A^T @ B kernel
    let c = Mat::from_vec(m, n, rng.gaussian_vec(m * n));
    set_level_override(Some(SimdLevel::Scalar));
    let want = a.matmul(&b);
    let want_atb = matmul_at_b(&a, &c);
    let mut want_blk = Mat::zeros(m - 2, n);
    matmul_block(&a, 1, m - 1, 0, &b, &mut want_blk);

    for level in supported_levels() {
        set_level_override(Some(level));
        let got = a.matmul(&b);
        assert!(
            want.data.iter().zip(&got.data).all(|(x, y)| x.to_bits() == y.to_bits()),
            "matmul not bitwise at level {}",
            level.name()
        );
        for tile in [1usize, 5, 64, 512, 10_000] {
            let mut tiled = vec![0.0f32; m * n];
            matmul_rows_tiled(&a, 0, m, &b, &mut tiled, tile);
            assert!(
                want.data.iter().zip(&tiled).all(|(x, y)| x.to_bits() == y.to_bits()),
                "tiled matmul not bitwise at level {} tile {tile}",
                level.name()
            );
        }
        let got_atb = matmul_at_b(&a, &c);
        assert!(
            want_atb.data.iter().zip(&got_atb.data).all(|(x, y)| x.to_bits() == y.to_bits()),
            "matmul_at_b not bitwise at level {}",
            level.name()
        );
        let mut got_blk = Mat::zeros(m - 2, n);
        matmul_block(&a, 1, m - 1, 0, &b, &mut got_blk);
        assert!(
            want_blk.data.iter().zip(&got_blk.data).all(|(x, y)| x.to_bits() == y.to_bits()),
            "matmul_block not bitwise at level {}",
            level.name()
        );
    }

    // clearing the override restores detection
    assert_eq!(set_level_override(None), detected);
}
