//! Property tests of the streaming subsystem (same seeded-generator
//! harness as `prop_favor.rs` — rerun any failure with the printed
//! seed):
//!
//!   * chunked `StreamState::advance` over *random* chunk splits equals
//!     single-shot `favor_unidirectional` (the refactor's contract);
//!   * the chunked native-model forward equals the single-shot forward;
//!   * the batched execution core: `forward_batch` over random ragged
//!     batches equals B independent `forward` calls, and fused
//!     `advance_batch` across random chunkings/session mixes equals the
//!     per-session sequential advance;
//!   * session budgeting: exceeding the budget evicts the LRU session
//!     and preserves the active/recent ones;
//!   * the coordinator stream path answers chunks incrementally, fusing
//!     same-window submissions.

use std::sync::Arc;

use performer::coordinator::Coordinator;
use performer::favor::linear::favor_unidirectional;
use performer::favor::{FeatureKind, FeatureMap};
use performer::linalg::OrfMechanism;
use performer::protein::vocab::{AA_BASE, N_AA};
use performer::rng::Pcg64;
use performer::runtime::EngineHandle;
use performer::stream::{ChunkScorer, SessionConfig, SessionManager, StreamState};
use performer::tensor::Mat;
use performer::train::{NativeModel, SyntheticConfig};

const CASES: u64 = 25;

/// Tiny property-test harness: runs `f` across seeded cases, panics with
/// the failing seed for reproduction.
fn forall(name: &str, f: impl Fn(&mut Pcg64)) {
    for seed in 0..CASES {
        let mut rng = Pcg64::new(0xbeef ^ seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            panic!("property '{name}' failed at seed {seed}: {e:?}");
        }
    }
}

fn rand_mat(rng: &mut Pcg64, r: usize, c: usize, scale: f32) -> Mat {
    Mat::from_vec(r, c, rng.gaussian_vec(r * c).iter().map(|v| v * scale).collect())
}

/// Random partition of [0, l) into non-empty contiguous chunks.
fn rand_splits(rng: &mut Pcg64, l: usize) -> Vec<(usize, usize)> {
    let mut cuts = vec![0usize, l];
    for _ in 0..rng.below(5) {
        cuts.push(1 + rng.below(l - 1));
    }
    cuts.sort_unstable();
    cuts.dedup();
    cuts.windows(2).map(|w| (w[0], w[1])).collect()
}

fn aa_tokens(rng: &mut Pcg64, n: usize) -> Vec<u8> {
    (0..n).map(|_| AA_BASE + rng.below(N_AA) as u8).collect()
}

#[test]
fn prop_chunked_equals_single_shot() {
    forall("chunked advance == favor_unidirectional", |rng| {
        let l = [8, 16, 24, 48, 64][rng.below(5)];
        let d = [2, 4, 8][rng.below(3)];
        let m = [4, 8, 16, 32][rng.below(4)];
        let fm = FeatureMap::sample(FeatureKind::Relu, m, d, OrfMechanism::Regular, rng);
        let qp = fm.apply(&rand_mat(rng, l, d, 0.5));
        let kp = fm.apply(&rand_mat(rng, l, d, 0.5));
        let v = rand_mat(rng, l, d, 1.0);

        let single = favor_unidirectional(&qp, &kp, &v);

        let mut state = StreamState::new(m, d);
        let mut streamed = Vec::with_capacity(l * d);
        for (lo, hi) in rand_splits(rng, l) {
            let out = state.advance(
                &qp.rows_slice(lo, hi),
                &kp.rows_slice(lo, hi),
                &v.rows_slice(lo, hi),
            );
            streamed.extend(out.data);
        }
        let streamed = Mat::from_vec(l, d, streamed);
        let diff = streamed.max_abs_diff(&single);
        assert!(diff < 1e-6, "chunked vs single-shot diff {diff}");
        assert_eq!(state.tokens_seen(), l as u64);
    });
}

#[test]
fn prop_chunked_model_forward_equals_single_shot() {
    let mut mrng = Pcg64::new(99);
    let model = Arc::new(NativeModel::synthetic(
        &SyntheticConfig { d_model: 16, n_heads: 2, n_layers: 2, d_ff: 32, ..Default::default() },
        &mut mrng,
    ));
    forall("chunked forward == forward", |rng| {
        let l = 16 + rng.below(48);
        let toks = aa_tokens(rng, l);
        let (single, _) = model.forward(&toks, false);

        let mut states = model.make_stream_states().unwrap();
        let mut streamed = Vec::new();
        for (lo, hi) in rand_splits(rng, l) {
            let logits = model.forward_chunk(&toks[lo..hi], lo, &mut states).unwrap();
            streamed.extend(logits.data);
        }
        let streamed = Mat::from_vec(l, model.vocab_size, streamed);
        let diff = streamed.max_abs_diff(&single);
        assert!(diff < 1e-4, "chunked model forward diverges by {diff}");
    });
}

#[test]
fn prop_redraw_chunked_equals_single_shot() {
    // a kernel with a live redraw schedule: epoch boundaries at every 24
    // tokens redraw the features and reset the attention context. Any
    // chunking — boundaries mid-chunk included — must reproduce the
    // single-shot forward, because the model splits chunks into
    // epoch-aligned segments internally.
    let mut mrng = Pcg64::new(103);
    let model = Arc::new(NativeModel::synthetic(
        &SyntheticConfig {
            d_model: 16,
            n_heads: 2,
            n_layers: 2,
            d_ff: 32,
            redraw_every: 24,
            ..Default::default()
        },
        &mut mrng,
    ));
    forall("redraw: chunked forward == forward", |rng| {
        let l = 30 + rng.below(70); // always crosses >= 1 boundary
        let toks = aa_tokens(rng, l);
        let (single, _) = model.forward(&toks, false);

        let mut states = model.make_stream_states().unwrap();
        let mut streamed = Vec::new();
        for (lo, hi) in rand_splits(rng, l) {
            let logits = model.forward_chunk(&toks[lo..hi], lo, &mut states).unwrap();
            streamed.extend(logits.data);
        }
        let streamed = Mat::from_vec(l, model.vocab_size, streamed);
        let diff = streamed.max_abs_diff(&single);
        assert!(diff < 1e-4, "redraw chunked forward diverges by {diff}");
    });
}

#[test]
fn scorer_pins_chunked_scoring_across_a_forced_redraw_boundary() {
    // the prop_stream satellite case: a session whose chunk sizes force
    // a redraw-epoch boundary mid-chunk and mid-session must score
    // exactly like the single-chunk session
    let mut rng = Pcg64::new(104);
    let model = Arc::new(NativeModel::synthetic(
        &SyntheticConfig { redraw_every: 16, ..Default::default() },
        &mut rng,
    ));
    let toks = aa_tokens(&mut rng, 45); // boundaries at 16 and 32

    let mut one = ChunkScorer::new(model.clone()).unwrap();
    let whole = one.advance(&toks).unwrap();

    let mut many = ChunkScorer::new(model.clone()).unwrap();
    let mut got = Vec::new();
    for (lo, hi) in [(0usize, 10usize), (10, 37), (37, 45)] {
        got.extend(many.advance(&toks[lo..hi]).unwrap().logprob);
    }
    assert_eq!(whole.logprob.len(), got.len());
    let max_diff = whole
        .logprob
        .iter()
        .zip(&got)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(
        max_diff < 1e-5,
        "redraw boundaries must be chunk-invariant (diff {max_diff})"
    );
    // both scorers ended in epoch 2 (position 44)
    for scorer in [&one, &many] {
        for layer in scorer.states() {
            for st in layer {
                assert_eq!(st.epoch(), 2);
            }
        }
    }
}

#[test]
fn prop_forward_batch_equals_independent_forwards() {
    let mut mrng = Pcg64::new(101);
    let model = Arc::new(NativeModel::synthetic(
        &SyntheticConfig { d_model: 16, n_heads: 2, n_layers: 2, d_ff: 32, ..Default::default() },
        &mut mrng,
    ));
    forall("forward_batch == B independent forwards", |rng| {
        let b = 1 + rng.below(4);
        // ragged on purpose: padding rows must not perturb real rows
        let seqs: Vec<Vec<u8>> = (0..b)
            .map(|_| {
                let n = 4 + rng.below(40);
                aa_tokens(rng, n)
            })
            .collect();
        let refs: Vec<&[u8]> = seqs.iter().map(|s| s.as_slice()).collect();
        let (batched, _) = model.forward_batch(&refs, false);
        for (s, seq) in seqs.iter().enumerate() {
            let (single, _) = model.forward(seq, false);
            let diff = batched[s].max_abs_diff(&single);
            assert!(diff < 1e-4, "seq {s} (len {}): batched diverges by {diff}", seq.len());
        }
    });
}

#[test]
fn prop_fused_chunk_advance_equals_sequential_advance() {
    let mut mrng = Pcg64::new(102);
    let model = Arc::new(NativeModel::synthetic(&SyntheticConfig::default(), &mut mrng));
    forall("advance_batch == per-session sequential advance", |rng| {
        let b = 1 + rng.below(4);
        let rounds = 1 + rng.below(3);
        let mut fused: Vec<ChunkScorer> =
            (0..b).map(|_| ChunkScorer::new(model.clone()).unwrap()).collect();
        let mut seq: Vec<ChunkScorer> =
            (0..b).map(|_| ChunkScorer::new(model.clone()).unwrap()).collect();
        for round in 0..rounds {
            // random chunk lengths per session per round: the fused
            // batch is ragged and sessions drift out of position sync
            let chunks: Vec<Vec<u8>> = (0..b)
                .map(|_| {
                    let n = 1 + rng.below(24);
                    aa_tokens(rng, n)
                })
                .collect();
            let refs: Vec<&[u8]> = chunks.iter().map(|c| c.as_slice()).collect();
            let got = ChunkScorer::advance_batch(&mut fused, &refs).unwrap();
            for s in 0..b {
                let want = seq[s].advance(&chunks[s]).unwrap();
                assert_eq!(got[s].offset, want.offset, "round {round} session {s}");
                assert_eq!(got[s].argmax, want.argmax, "round {round} session {s}");
                let diff = got[s]
                    .logprob
                    .iter()
                    .zip(&want.logprob)
                    .map(|(a, b)| (a - b).abs())
                    .fold(0.0f32, f32::max);
                assert!(
                    diff < 1e-5,
                    "round {round} session {s}: fused diverges by {diff}"
                );
            }
        }
    });
}

#[test]
fn scorer_state_is_constant_and_positions_advance() {
    let mut rng = Pcg64::new(3);
    let model = Arc::new(NativeModel::synthetic(&SyntheticConfig::default(), &mut rng));
    let mut scorer = ChunkScorer::new(model).unwrap();
    let bytes = scorer.state_bytes();
    let mut expect_offset = 0;
    for i in 0..6 {
        let n = 16 + (i * 7) % 32;
        let s = scorer.advance(&aa_tokens(&mut rng, n)).unwrap();
        assert_eq!(s.offset, expect_offset);
        assert_eq!(s.len(), n);
        expect_offset += n;
        assert_eq!(scorer.state_bytes(), bytes, "state must not grow");
    }
    assert_eq!(scorer.tokens_seen(), expect_offset);
}

#[test]
fn session_budget_evicts_lru_preserves_active() {
    let mut rng = Pcg64::new(5);
    let model = Arc::new(NativeModel::synthetic(&SyntheticConfig::default(), &mut rng));
    let per = SessionManager::new(model.clone(), SessionConfig::default())
        .unwrap()
        .per_session_bytes();

    // budget: exactly three resident sessions
    let cfg = SessionConfig { max_state_bytes: 3 * per, ..Default::default() };
    let mut mgr = SessionManager::new(model, cfg).unwrap();
    for id in ["a", "b", "c"] {
        mgr.advance(id, &aa_tokens(&mut rng, 16)).unwrap();
    }
    // touch "a" so "b" becomes the LRU
    mgr.advance("a", &aa_tokens(&mut rng, 16)).unwrap();
    // a fourth stream must push out exactly the LRU ("b")
    mgr.advance("d", &aa_tokens(&mut rng, 16)).unwrap();

    assert!(!mgr.contains("b"), "LRU session must be evicted");
    assert!(mgr.contains("a"), "recently touched session must survive");
    assert!(mgr.contains("c"), "under-budget session must survive");
    assert!(mgr.contains("d"), "active session must never be evicted");
    assert_eq!(mgr.stats().evicted, 1);
    assert!(mgr.resident_bytes() <= 3 * per);

    // explicit close releases the remaining state
    for id in ["a", "c", "d"] {
        assert!(mgr.close(id));
    }
    assert_eq!(mgr.resident_bytes(), 0);
}

#[test]
fn coordinator_fused_submissions_round_trip() {
    let mut rng = Pcg64::new(21);
    let model = Arc::new(NativeModel::synthetic(&SyntheticConfig::default(), &mut rng));
    let mut coord = Coordinator::new(EngineHandle::disconnected("artifacts"));
    coord.start_stream_pool("native", model, SessionConfig::default()).unwrap();

    // 8 sessions submit together each round: the worker drains them
    // into fused batches, yet every session advances independently
    for round in 0usize..3 {
        let reqs: Vec<(String, Vec<u8>)> =
            (0..8).map(|u| (format!("u{u}"), aa_tokens(&mut rng, 24 + u))).collect();
        let lens: Vec<usize> = reqs.iter().map(|(_, t)| t.len()).collect();
        let rxs = coord.submit_chunks("native", reqs).unwrap();
        for (u, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv().unwrap();
            assert!(resp.ok(), "round {round} u{u}: {:?}", resp.error);
            let scores = resp.scores.expect("scores for a chunk request");
            assert_eq!(scores.offset, round * (24 + u), "per-session offsets must advance");
            assert_eq!(scores.len(), lens[u]);
        }
    }
    coord.shutdown();
}

#[test]
fn coordinator_stream_path_round_trips() {
    let mut rng = Pcg64::new(7);
    let model = Arc::new(NativeModel::synthetic(&SyntheticConfig::default(), &mut rng));
    let mut coord = Coordinator::new(EngineHandle::disconnected("artifacts"));
    coord
        .start_stream_pool("native", model, SessionConfig::default())
        .unwrap();

    // two users interleave chunks; offsets advance per session
    for round in 0..3 {
        for user in ["u1", "u2"] {
            let resp = coord
                .stream_chunk("native", user, aa_tokens(&mut rng, 32))
                .unwrap();
            let scores = resp.scores.expect("scores for a chunk request");
            assert_eq!(scores.offset, round * 32);
            assert_eq!(scores.len(), 32);
            assert!(resp.resident_bytes > 0);
        }
    }
    coord.close_stream("native", "u1").unwrap();
    let resp = coord.stream_chunk("native", "u2", aa_tokens(&mut rng, 8)).unwrap();
    assert_eq!(resp.resident_sessions, 1, "closed session must be released");

    // unknown pool is an error; a bidirectional model cannot stream
    assert!(coord.stream_chunk("nope", "u", vec![AA_BASE]).is_err());
    let mut rng2 = Pcg64::new(8);
    let bid = Arc::new(NativeModel::synthetic(
        &SyntheticConfig {
            direction: performer::favor::Direction::Bidirectional,
            ..Default::default()
        },
        &mut rng2,
    ));
    assert!(coord.start_stream_pool("bid", bid, SessionConfig::default()).is_err());
    coord.shutdown();
}
