//! Integration tests over the PJRT runtime + training driver: artifact
//! loading, train-step execution, checkpoint roundtrip, feature
//! resampling and the eval contract. Requires `make artifacts`.

use std::path::PathBuf;
use std::sync::Arc;

use performer::protein::{Corpus, CorpusConfig};
use performer::rng::Pcg64;
use performer::runtime::Engine;
use performer::train::{run_training, LoopOptions, Split, TrainState};

fn artifacts() -> PathBuf {
    PathBuf::from("artifacts")
}

fn built() -> bool {
    artifacts().join("tiny_relu_bid_train.hlo.txt").exists()
}

fn new_state() -> (Arc<Engine>, TrainState) {
    let engine = Arc::new(Engine::new(artifacts()).unwrap());
    let state = TrainState::new(engine.clone(), "tiny_relu_bid").unwrap();
    (engine, state)
}

#[test]
fn train_step_reduces_loss() {
    if !built() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let (_e, mut state) = new_state();
    let corpus = Arc::new(Corpus::generate(CorpusConfig::default()));
    let mut gen = state.data_gen(corpus, 0);
    let opts = LoopOptions {
        steps: 12,
        eval_every: 0,
        eval_batches: 0,
        log_every: 100,
        resample_every: 0,
        quiet: true,
    };
    let curve = run_training(&mut state, &mut gen, &opts, 0).unwrap();
    let first = curve.train.first().unwrap().loss;
    let last = curve.train.last().unwrap().loss;
    assert!(last < first, "loss did not decrease: {first} -> {last}");
    assert!(state.step as usize == 12);
}

#[test]
fn eval_is_deterministic_and_stateless() {
    if !built() {
        return;
    }
    let (_e, state) = new_state();
    let corpus = Arc::new(Corpus::generate(CorpusConfig::default()));
    let mut gen1 = state.data_gen(corpus.clone(), 5);
    let mut gen2 = state.data_gen(corpus, 5);
    let (l1, a1) = state.evaluate(&mut gen1, Split::Test, 2).unwrap();
    let (l2, a2) = state.evaluate(&mut gen2, Split::Test, 2).unwrap();
    assert_eq!(l1, l2);
    assert_eq!(a1, a2);
}

#[test]
fn checkpoint_roundtrip_preserves_state() {
    if !built() {
        return;
    }
    let (engine, mut state) = new_state();
    let corpus = Arc::new(Corpus::generate(CorpusConfig::default()));
    let mut gen = state.data_gen(corpus.clone(), 1);
    for _ in 0..3 {
        let b = gen.next_batch(Split::Train);
        state.train_step(&b).unwrap();
    }
    let path = std::env::temp_dir().join("performer_ckpt_test.bin");
    state.save_checkpoint(&path).unwrap();

    let mut restored = TrainState::new(engine, "tiny_relu_bid").unwrap();
    restored.load_checkpoint(&path).unwrap();
    assert_eq!(restored.step, state.step);
    for (a, b) in state.params.iter().zip(&restored.params) {
        assert_eq!(a, b);
    }
    // eval parity proves the restored state is functionally identical
    let mut g1 = state.data_gen(corpus.clone(), 9);
    let mut g2 = restored.data_gen(corpus, 9);
    let (l1, _) = state.evaluate(&mut g1, Split::Valid, 2).unwrap();
    let (l2, _) = restored.evaluate(&mut g2, Split::Valid, 2).unwrap();
    assert_eq!(l1, l2);
}

#[test]
fn feature_resampling_changes_projection_but_keeps_model_sane() {
    if !built() {
        return;
    }
    let (_e, mut state) = new_state();
    // check the "w" slot specifically (the "b" slot is zeros for ReLU
    // features and legitimately survives a redraw unchanged)
    let w_idx = state.feature_names.iter().position(|n| n == "w").unwrap();
    let before = state.features[w_idx].clone();
    let mut rng = Pcg64::new(3);
    state.resample_features(&mut rng).unwrap();
    let after = state.features[w_idx].clone();
    assert_ne!(before, after, "resample must redraw W");
    // model still evaluates finitely after redraw
    let corpus = Arc::new(Corpus::generate(CorpusConfig::default()));
    let mut gen = state.data_gen(corpus, 2);
    let (loss, acc) = state.evaluate(&mut gen, Split::Valid, 1).unwrap();
    assert!(loss.is_finite() && acc.is_finite());
}

#[test]
fn transplant_copies_matching_tensors() {
    if !built() {
        return;
    }
    let engine = Arc::new(Engine::new(artifacts()).unwrap());
    let donor = TrainState::new(engine.clone(), "tiny_relu_bid").unwrap();
    let mut recipient = TrainState::new(engine, "tiny_relu_bid").unwrap();
    // scramble the recipient first
    for p in recipient.params.iter_mut() {
        for v in p.iter_mut() {
            *v += 1.0;
        }
    }
    let copied = recipient.transplant_from(&donor);
    assert_eq!(copied, donor.params.len());
    for (a, b) in donor.params.iter().zip(&recipient.params) {
        assert_eq!(a, b);
    }
}

#[test]
fn corrupt_batch_size_is_rejected() {
    if !built() {
        return;
    }
    let (_e, mut state) = new_state();
    let bad = performer::protein::Batch::new(1, 8); // wrong shape
    assert!(state.train_step(&bad).is_err());
}
