//! End-to-end tests of the networked serving tier, all over real
//! loopback sockets:
//!
//!   * a worker served over TCP returns **bitwise** the same per-token
//!     scores as the same model driven in-process;
//!   * a router over two workers keeps streams bit-exact across a live
//!     `admin_drain` migration — including after the drained worker is
//!     shut down;
//!   * a saturated inflight gate sheds with `RetryAfter`, counts the
//!     shed, and the shed submit retries cleanly (the stream did not
//!     advance).

use std::sync::Arc;

use anyhow::Result;
use performer::coordinator::Coordinator;
use performer::net::{Client, Router, RoutingTable, Server, ServerConfig};
use performer::protein::Corpus;
use performer::rng::Pcg64;
use performer::runtime::EngineHandle;
use performer::stream::SessionConfig;
use performer::train::{NativeModel, SyntheticConfig};

const POOL: &str = "native";
const CHUNK: usize = 24;
const ROUNDS: usize = 6;
const SESSIONS: usize = 2;

/// The deterministic synthetic stack every peer builds: same seed, same
/// weights, so wire-vs-local diffs isolate the transport.
fn model() -> Arc<NativeModel> {
    let cfg = SyntheticConfig::default();
    Arc::new(NativeModel::synthetic(&cfg, &mut Pcg64::new(0)))
}

fn coordinator() -> Result<Coordinator> {
    let mut coord = Coordinator::new(EngineHandle::disconnected(std::env::temp_dir()));
    coord.start_stream_pool(POOL, model(), SessionConfig::default())?;
    Ok(coord)
}

/// A worker on an ephemeral loopback port.
fn worker(max_inflight: usize) -> Result<Server> {
    let cfg = ServerConfig { max_inflight, ..ServerConfig::default() };
    Server::start(Arc::new(coordinator()?), "127.0.0.1:0", cfg)
}

/// The CLI's seeded workload: `[round][session] -> chunk tokens`.
fn schedule() -> Vec<Vec<Vec<u8>>> {
    let corpus = Corpus::generate(Default::default());
    let mut rng = Pcg64::new(42);
    (0..ROUNDS)
        .map(|_| {
            (0..SESSIONS)
                .map(|_| corpus.concat_stream(CHUNK, 1, &mut rng).pop().unwrap())
                .collect()
        })
        .collect()
}

/// Per-session score bits from driving the schedule in-process — the
/// ground truth every wire path must reproduce exactly.
fn in_process_bits() -> Result<Vec<Vec<(usize, u32)>>> {
    let coord = coordinator()?;
    let mut bits = vec![Vec::new(); SESSIONS];
    for round in schedule() {
        for (s, tokens) in round.into_iter().enumerate() {
            let resp = coord.stream_chunk(POOL, &format!("user-{s}"), tokens)?;
            let scores = resp.scores.expect("chunk response carries scores");
            for (p, lp) in scores.logprob.iter().enumerate() {
                bits[s].push((scores.offset + p, lp.to_bits()));
            }
        }
    }
    Ok(bits)
}

fn push_scores(bits: &mut [Vec<(usize, u32)>], s: usize, scores: &performer::stream::ChunkScores) {
    for (p, lp) in scores.logprob.iter().enumerate() {
        bits[s].push((scores.offset + p, lp.to_bits()));
    }
}

#[test]
fn wire_scores_are_bitwise_identical_to_in_process() -> Result<()> {
    let baseline = in_process_bits()?;

    let srv = worker(0)?;
    let mut client = Client::connect(&srv.local_addr().to_string())?;
    let mut bits = vec![Vec::new(); SESSIONS];
    for s in 0..SESSIONS {
        client.open(POOL, &format!("user-{s}"))?;
    }
    for round in schedule() {
        for (s, tokens) in round.into_iter().enumerate() {
            let scores = client.submit(POOL, &format!("user-{s}"), &tokens)?;
            push_scores(&mut bits, s, &scores);
        }
    }
    for s in 0..SESSIONS {
        client.close(POOL, &format!("user-{s}"))?;
    }
    assert_eq!(bits, baseline, "wire scores drifted from the in-process run");
    assert!(srv.metrics().requests.get() >= (ROUNDS * SESSIONS) as u64);
    Ok(())
}

#[test]
fn router_keeps_streams_bit_exact_across_live_migration() -> Result<()> {
    let baseline = in_process_bits()?;

    let mut w0 = worker(0)?;
    let w1 = worker(0)?;
    let shards = vec![w0.local_addr().to_string(), w1.local_addr().to_string()];
    let router = Router::start("127.0.0.1:0", shards)?;
    let mut client = Client::connect(&router.local_addr().to_string())?;

    // the workload sessions land on *different* shards under the
    // initial slot deal (pinned by a router unit test), so the drain
    // below genuinely moves a mid-stream session between processes
    let table = RoutingTable::new(vec!["a".into(), "b".into()])?;
    assert_eq!(table.shard_of("user-0"), 1);
    assert_eq!(table.shard_of("user-1"), 0);

    let mut bits = vec![Vec::new(); SESSIONS];
    let plan = schedule();
    for round in plan.iter().take(3) {
        for (s, tokens) in round.iter().enumerate() {
            let scores = client.submit(POOL, &format!("user-{s}"), tokens)?;
            push_scores(&mut bits, s, &scores);
        }
    }

    // live rebalance: evacuate shard 0 (user-1's home) into shard 1,
    // then retire the drained worker entirely — the remaining rounds
    // must not notice
    let moved = client.admin_drain(POOL, 0, 1)?;
    assert!(moved >= 1, "expected at least user-1 to migrate, moved {moved}");
    w0.shutdown();
    drop(w0);

    for round in plan.iter().skip(3) {
        for (s, tokens) in round.iter().enumerate() {
            let scores = client.submit(POOL, &format!("user-{s}"), tokens)?;
            push_scores(&mut bits, s, &scores);
        }
    }
    for s in 0..SESSIONS {
        client.close(POOL, &format!("user-{s}"))?;
    }
    assert_eq!(bits, baseline, "migrated streams drifted from the in-process run");
    assert!(router.metrics().drains.get() >= 1);
    Ok(())
}

#[test]
fn saturated_gate_sheds_and_shed_submit_retries_cleanly() -> Result<()> {
    let srv = worker(2)?;
    let addr = srv.local_addr().to_string();
    let mut client = Client::connect(&addr)?;
    client.open(POOL, "user-0")?;

    // one served chunk so the retry below must *continue* the stream
    let tokens: Vec<u8> = schedule()[0][0].clone();
    let first = client.submit(POOL, "user-0", &tokens)?;
    assert_eq!(first.offset, 0);

    // saturate the admission gate from the test thread; a submit now
    // has no permit to take and must shed
    let gate = srv.gate();
    let p0 = gate.try_acquire().expect("gate has capacity");
    let p1 = gate.try_acquire().expect("gate has capacity");
    assert!(gate.try_acquire().is_none(), "gate should be saturated");

    let shed_base = srv.metrics().sheds.get();
    let mut impatient = Client::connect(&addr)?;
    impatient.retries = 0;
    let err = impatient
        .submit(POOL, "user-0", &tokens)
        .expect_err("a saturated gate must shed, not serve");
    assert!(format!("{err:#}").contains("busy"), "unexpected shed error: {err:#}");
    assert!(srv.metrics().sheds.get() > shed_base, "shed was not counted");

    // free the gate: the *same* submit now succeeds, and its offset
    // proves the shed attempt never advanced the stream
    drop(p0);
    drop(p1);
    let second = client.submit(POOL, "user-0", &tokens)?;
    assert_eq!(second.offset, tokens.len(), "shed attempt advanced the stream");
    client.close(POOL, "user-0")?;
    Ok(())
}
