//! Cross-validation: the native rust forward pass must agree with the
//! AOT HLO (whose FAVOR attention runs through the Pallas kernels) on
//! identical weights and tokens. This pins L1 (Pallas), L2 (jax model)
//! and the L3 native reimplementation to the same math.
//!
//! Requires `make artifacts`.

use std::path::PathBuf;

use performer::protein::{Corpus, CorpusConfig};
use performer::rng::Pcg64;
use performer::runtime::{ArtifactMeta, Engine, HostValue, Role, TensorFile};
use performer::train::NativeModel;

fn artifacts() -> PathBuf {
    // tests run from the crate root
    PathBuf::from("artifacts")
}

fn have(tag: &str) -> bool {
    artifacts().join(format!("{tag}.hlo.txt")).exists()
}

fn hlo_logits(engine: &Engine, tag: &str, tokens: &[i32]) -> Vec<f32> {
    let exe = engine.load(&format!("{tag}_fwd")).expect("load fwd");
    let init = TensorFile::read(&artifacts().join(format!("{tag}_init.bin"))).unwrap();
    let mut inputs = Vec::new();
    for slot in &exe.meta.inputs {
        inputs.push(match slot.role {
            Role::Param => HostValue::F32(
                init.get(&format!("param:{}", slot.name)).unwrap().1.to_vec(),
            ),
            Role::Feature => HostValue::F32(
                init.get(&format!("feature:{}", slot.name)).unwrap().1.to_vec(),
            ),
            Role::Tokens => HostValue::I32(tokens.to_vec()),
            _ => panic!("unexpected role"),
        });
    }
    exe.run(&inputs).unwrap()[0].as_f32().unwrap().to_vec()
}

fn native_logits(tag: &str, tokens: &[u8]) -> Vec<f32> {
    let meta = ArtifactMeta::load(&artifacts(), &format!("{tag}_fwd")).unwrap();
    let init = TensorFile::read(&artifacts().join(format!("{tag}_init.bin"))).unwrap();
    let lookup = move |name: &str| -> Option<Vec<f32>> {
        init.get(&format!("param:{name}"))
            .or_else(|| init.get(&format!("feature:{name}")))
            .map(|(_, d)| d.to_vec())
    };
    let model = NativeModel::from_weights(&meta, &lookup).unwrap();
    model.forward(tokens, false).0.data
}

fn check_tag(tag: &str, tol: f32) {
    if !have(&format!("{tag}_fwd")) {
        eprintln!("skipping {tag}: artifacts not built");
        return;
    }
    let engine = Engine::new(artifacts()).unwrap();
    let meta = ArtifactMeta::load(&artifacts(), &format!("{tag}_fwd")).unwrap();
    let (b, l) = (meta.config.batch, meta.config.max_len);

    // real protein tokens for the whole batch
    let corpus = Corpus::generate(CorpusConfig::default());
    let mut rng = Pcg64::new(1);
    let windows: Vec<Vec<u8>> =
        (0..b).map(|_| corpus.window(&corpus.sample_iid(&mut rng).1, l)).collect();
    let tokens_i32: Vec<i32> =
        windows.iter().flatten().map(|&t| t as i32).collect();

    let hlo = hlo_logits(&engine, tag, &tokens_i32);
    let vocab = meta.config.vocab_size;

    // native runs one sequence at a time; compare row 0 and row b-1
    for row in [0, b - 1] {
        let native = native_logits(tag, &windows[row]);
        let hlo_row = &hlo[row * l * vocab..(row + 1) * l * vocab];
        let mut max_diff = 0.0f32;
        for (a, b_) in native.iter().zip(hlo_row) {
            max_diff = max_diff.max((a - b_).abs());
        }
        assert!(
            max_diff < tol,
            "{tag} row {row}: native vs HLO logits diverge by {max_diff}"
        );
    }
}

#[test]
fn native_matches_hlo_favor_relu() {
    // HLO fwd contains the Pallas kernels; native is pure rust — both
    // implement the same FAVOR math.
    check_tag("tiny_relu_bid", 2e-3);
}

#[test]
fn native_matches_hlo_exact() {
    check_tag("base_exact_bid", 2e-3);
}

#[test]
fn native_matches_hlo_base_favor() {
    check_tag("base_perf_relu_bid", 5e-3);
}
