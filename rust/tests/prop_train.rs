//! Property tests for sub-linear-memory chunked training (SLiM).
//!
//! The gradient oracle is the full-sequence path: `chunked_loss_and_grad`
//! with `chunk_len = 0` runs one segment per redraw epoch (one segment
//! total without redraws) through the very same forward/backward code.
//! Chunked runs must reproduce its loss and per-parameter gradients up
//! to float reassociation across chunk boundaries — and bitwise when
//! the chunking degenerates to a single segment.

use performer::favor::FeatureKind;
use performer::protein::{lm_batch, Batch};
use performer::rng::Pcg64;
use performer::stream::StatePrecision;
use performer::train::{
    chunked_loss_and_grad, plan_segments, ChunkedTrainConfig, DataGen, NativeModel, NativeTrainer,
    ParamGrads, RecomputePolicy, Split, SyntheticConfig,
};

fn synth(d: usize, h: usize, nl: usize, dff: usize, m: usize, redraw: u64) -> SyntheticConfig {
    SyntheticConfig {
        d_model: d,
        n_heads: h,
        n_layers: nl,
        d_ff: dff,
        n_features: m,
        kind: FeatureKind::Relu,
        redraw_every: redraw,
        ..SyntheticConfig::default()
    }
}

/// Random all-real-token LM batch (ragged rows exercise zero-weight
/// padding in the last column via `lm_batch` itself).
fn random_batch(b: usize, l: usize, seed: u64) -> Batch {
    let mut rng = Pcg64::new(seed);
    let windows: Vec<Vec<u8>> = (0..b)
        .map(|_| (0..l).map(|_| (4 + rng.below(25)) as u8).collect())
        .collect();
    lm_batch(&windows, l)
}

/// Per-parameter tolerance oracle: every gradient slot of `got` must
/// match `want` within `atol + rtol * max|want slot|` elementwise.
fn assert_grads_close(want: &ParamGrads, got: &ParamGrads, rtol: f32, atol: f32, ctx: &str) {
    for ((name_w, w), (name_g, g)) in want.slots().iter().zip(got.slots().iter()) {
        assert_eq!(name_w, name_g, "{ctx}: slot order diverged");
        assert_eq!(w.len(), g.len(), "{ctx}: slot {name_w} length");
        let scale = w.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
        let tol = atol + rtol * scale;
        for (k, (&x, &y)) in w.iter().zip(g.iter()).enumerate() {
            assert!(
                (x - y).abs() <= tol,
                "{ctx}: {name_w}[{k}] full {x:.6e} vs chunked {y:.6e} (tol {tol:.3e})"
            );
        }
    }
}

fn run(
    model: &NativeModel,
    batch: &Batch,
    cfg: &ChunkedTrainConfig,
) -> (f32, ParamGrads, usize) {
    let mut grads = ParamGrads::zeros_like(model);
    let out = chunked_loss_and_grad(model, batch, cfg, &mut grads).expect("loss+grad");
    (out.loss, grads, out.mem.segments)
}

#[test]
fn chunked_gradients_match_full_sequence_oracle() {
    // (d, heads, layers, d_ff, M, L, B, redraw_every, chunk_len):
    // chunk lengths cover 1, L, and non-dividing L_c; one geometry
    // forces mid-sequence redraw boundaries on top of the chunk grid.
    let geometries: [(usize, usize, usize, usize, usize, usize, usize, u64, usize); 4] = [
        (16, 2, 2, 24, 12, 24, 2, 0, 5),
        (16, 2, 1, 24, 12, 12, 1, 0, 1),
        (16, 2, 2, 24, 12, 20, 2, 8, 6),
        (8, 1, 1, 16, 8, 16, 2, 0, 16),
    ];
    for (ti, &(d, h, nl, dff, m, l, b, redraw, lc)) in geometries.iter().enumerate() {
        let syn = synth(d, h, nl, dff, m, redraw);
        let model = NativeModel::synthetic(&syn, &mut Pcg64::new(40 + ti as u64));
        let batch = random_batch(b, l, 90 + ti as u64);
        let full = ChunkedTrainConfig::default();
        let (loss_f, g_full, _) = run(&model, &batch, &full);
        let chunked = ChunkedTrainConfig { chunk_len: lc, ..full };
        let (loss_c, g_chunk, segments) = run(&model, &batch, &chunked);
        let expected_segments = plan_segments(&model, l, lc).unwrap().len();
        assert_eq!(segments, expected_segments, "geometry {ti}: segment count");
        if lc < l || redraw > 0 {
            assert!(segments > 1, "geometry {ti} should actually chunk");
        }
        assert!(
            (loss_f - loss_c).abs() <= 1e-5 * (1.0 + loss_f.abs()),
            "geometry {ti}: loss full {loss_f} vs chunked {loss_c}"
        );
        // chunking only reassociates float sums; deltas stay tiny
        assert_grads_close(&g_full, &g_chunk, 1e-3, 1e-5, &format!("geometry {ti}"));
    }
}

#[test]
fn chunked_gradients_bf16_states_match_bf16_oracle() {
    // with bf16 carried sums, the chunked run and the bf16 full-sequence
    // run quantize identically token-by-token (boundary clones preserve
    // the quantized image), so they still agree to reassociation
    for (ti, lc) in [3usize, 7].into_iter().enumerate() {
        let syn = synth(16, 2, 2, 24, 12, 0);
        let model = NativeModel::synthetic(&syn, &mut Pcg64::new(70 + ti as u64));
        let batch = random_batch(2, 18, 170 + ti as u64);
        let bf16 = ChunkedTrainConfig {
            precision: StatePrecision::Bf16,
            ..ChunkedTrainConfig::default()
        };
        let (loss_f, g_full, _) = run(&model, &batch, &bf16);
        let chunked = ChunkedTrainConfig { chunk_len: lc, ..bf16 };
        let (loss_c, g_chunk, segs) = run(&model, &batch, &chunked);
        assert!(segs > 1);
        assert!(
            (loss_f - loss_c).abs() <= 1e-5 * (1.0 + loss_f.abs()),
            "bf16 chunk {lc}: loss full {loss_f} vs chunked {loss_c}"
        );
        assert_grads_close(&g_full, &g_chunk, 1e-3, 1e-5, &format!("bf16 chunk {lc}"));
    }
}

#[test]
fn single_chunk_degenerate_is_bitwise_identical() {
    // chunk_len >= L with no redraws plans exactly one segment — the
    // same execution as the full-sequence oracle, so every gradient is
    // bit-for-bit equal, not merely close
    let syn = synth(16, 2, 2, 24, 12, 0);
    let model = NativeModel::synthetic(&syn, &mut Pcg64::new(11));
    let batch = random_batch(2, 14, 211);
    let full = ChunkedTrainConfig::default();
    let (loss_f, g_full, seg_f) = run(&model, &batch, &full);
    let one = ChunkedTrainConfig { chunk_len: 14, ..full };
    let (loss_c, g_chunk, seg_c) = run(&model, &batch, &one);
    assert_eq!(seg_f, 1);
    assert_eq!(seg_c, 1);
    assert_eq!(loss_f.to_bits(), loss_c.to_bits());
    for ((name, w), (_, g)) in g_full.slots().iter().zip(g_chunk.slots().iter()) {
        for (k, (&x, &y)) in w.iter().zip(g.iter()).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{name}[{k}] not bitwise equal");
        }
    }
}

#[test]
fn retain_matches_recompute_bitwise() {
    // Retain keeps the pass-1 tapes; Recompute replays each chunk from
    // its boundary checkpoint. The replay is the same arithmetic, so
    // the two policies must agree bit-for-bit.
    let syn = synth(16, 2, 2, 24, 12, 8);
    let model = NativeModel::synthetic(&syn, &mut Pcg64::new(23));
    let batch = random_batch(2, 20, 223);
    let rec = ChunkedTrainConfig { chunk_len: 6, ..ChunkedTrainConfig::default() };
    let (loss_r, g_rec, _) = run(&model, &batch, &rec);
    let ret = ChunkedTrainConfig { policy: RecomputePolicy::Retain, ..rec };
    let (loss_t, g_ret, _) = run(&model, &batch, &ret);
    assert_eq!(loss_r.to_bits(), loss_t.to_bits());
    for ((name, w), (_, g)) in g_rec.slots().iter().zip(g_ret.slots().iter()) {
        for (k, (&x, &y)) in w.iter().zip(g.iter()).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{name}[{k}]: retain != recompute");
        }
    }
}

#[test]
fn plan_segments_cut_at_chunk_grid_and_redraw_boundaries() {
    let syn = synth(16, 2, 2, 24, 12, 8);
    let model = NativeModel::synthetic(&syn, &mut Pcg64::new(31));
    let segs = plan_segments(&model, 20, 6).unwrap();
    // cuts at multiples of 6 (chunk grid) and 8 (redraw), tiling [0,20)
    assert_eq!(segs, vec![(0, 6), (6, 8), (8, 12), (12, 16), (16, 18), (18, 20)]);
    let full = plan_segments(&model, 20, 0).unwrap();
    assert_eq!(full, vec![(0, 8), (8, 16), (16, 20)]);
}

#[test]
fn trainer_checkpoint_roundtrip_resumes_identical_curve() {
    // satellite: mid-run checkpoint interplay with chunked mode — a
    // trainer restored from step 3's checkpoint must replay steps 4..6
    // to bitwise-identical losses (params, Adam moments and the step
    // counter all round-trip)
    let syn = synth(16, 2, 1, 24, 12, 0);
    let cfg = ChunkedTrainConfig { chunk_len: 5, ..ChunkedTrainConfig::default() };
    let batches: Vec<Batch> = (0..6).map(|i| random_batch(2, 15, 300 + i)).collect();
    let path = std::env::temp_dir().join("performer_prop_train_ckpt.bin");

    let model = NativeModel::synthetic(&syn, &mut Pcg64::new(47));
    let mut a = NativeTrainer::new(model, cfg, 1e-3, "a").unwrap();
    for b in &batches[..3] {
        a.train_step(b).unwrap();
    }
    a.save_checkpoint(&path).unwrap();
    let tail_a: Vec<f32> =
        batches[3..].iter().map(|b| a.train_step(b).unwrap().0).collect();

    // different init on purpose: the checkpoint must fully determine it
    let model = NativeModel::synthetic(&syn, &mut Pcg64::new(48));
    let mut b = NativeTrainer::new(model, cfg, 1e-3, "b").unwrap();
    b.load_checkpoint(&path).unwrap();
    assert_eq!(b.step(), 3.0);
    let tail_b: Vec<f32> =
        batches[3..].iter().map(|bt| b.train_step(bt).unwrap().0).collect();
    for (i, (x, y)) in tail_a.iter().zip(&tail_b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "resumed step {} loss diverged", 4 + i);
    }
    let _ = std::fs::remove_file(&path);
}

#[test]
fn datagen_streams_are_bitwise_deterministic() {
    // satellite: same corpus + seed => two independent generators
    // produce bitwise-identical batch streams, per split, under
    // interleaved draws from other splits
    let corpus = std::sync::Arc::new(performer::protein::Corpus::generate(Default::default()));
    let mut g1 = DataGen::new(corpus.clone(), 32, 3, true, false, 77);
    let mut g2 = DataGen::new(corpus.clone(), 32, 3, true, false, 77);
    // interleave: split streams must be independent of draw order
    let _ = g1.next_batch(Split::Valid);
    let _ = g1.next_batch(Split::Ood);
    let a1 = g1.next_batch(Split::Train);
    let _ = g2.next_batch(Split::Test);
    let a2 = g2.next_batch(Split::Train);
    assert_eq!(a1.tokens, a2.tokens);
    assert_eq!(a1.targets, a2.targets);
    assert_eq!(a1.weights, a2.weights);
    let b1 = g1.next_batch(Split::Train);
    let b2 = g2.next_batch(Split::Train);
    assert_eq!(b1.tokens, b2.tokens);
    assert_ne!(a1.tokens, b1.tokens, "stream should advance");
    let mut g3 = DataGen::new(corpus, 32, 3, true, false, 78);
    let a3 = g3.next_batch(Split::Train);
    assert_ne!(a1.tokens, a3.tokens, "different seed should differ");
}
