//! Property tests of the durable-persistence subsystem (same
//! seeded-generator harness as `prop_stream.rs` — rerun any failure with
//! the printed seed):
//!
//!   * spill → rehydrate → advance is *bitwise* identical to an
//!     uninterrupted session, across random chunkings and random
//!     forced-eviction schedules (the tentpole's core contract);
//!   * snapshot encode/decode round-trips exactly, and corrupt or
//!     truncated snapshot files / manifests fail loudly instead of
//!     restoring garbage;
//!   * `Coordinator::checkpoint_all` + a fresh coordinator +
//!     `restore_from` reproduces the exact per-token output of an
//!     uninterrupted run (in-process kill-and-restore);
//!   * any interleaving of full + delta checkpoints restores bitwise
//!     identical to one full export, and every delta writes exactly the
//!     sessions dirtied since the previous export (O(k) snapshot IO).

use std::path::PathBuf;
use std::sync::Arc;

use performer::coordinator::Coordinator;
use performer::persist::{Checkpointer, SessionSnapshot};
use performer::protein::vocab::{AA_BASE, N_AA};
use performer::rng::Pcg64;
use performer::runtime::EngineHandle;
use performer::stream::{ChunkScorer, ChunkScores, SessionConfig, SessionManager};
use performer::train::{NativeModel, SyntheticConfig};

const CASES: u64 = 15;

/// Tiny property-test harness: runs `f` across seeded cases, panics with
/// the failing seed for reproduction.
fn forall(name: &str, f: impl Fn(&mut Pcg64)) {
    for seed in 0..CASES {
        let mut rng = Pcg64::new(0xd15c ^ seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            panic!("property '{name}' failed at seed {seed}: {e:?}");
        }
    }
}

fn aa_tokens(rng: &mut Pcg64, n: usize) -> Vec<u8> {
    (0..n).map(|_| AA_BASE + rng.below(N_AA) as u8).collect()
}

fn tempdir(tag: &str, seed: u64) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("pfrm_prop_{tag}_{seed}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn bits(s: &ChunkScores) -> Vec<u32> {
    s.logprob.iter().map(|v| v.to_bits()).collect()
}

#[test]
fn prop_spill_rehydrate_is_bitwise_transparent() {
    let mut mrng = Pcg64::new(7001);
    let model = Arc::new(NativeModel::synthetic(&SyntheticConfig::default(), &mut mrng));
    let per = SessionManager::new(model.clone(), SessionConfig::default())
        .unwrap()
        .per_session_bytes();
    forall("spill -> rehydrate -> advance == uninterrupted", |rng| {
        let seed_tag = rng.below(1 << 30) as u64;
        let dir = tempdir("spill", seed_tag);
        // a one-session budget: every session switch forces a spill of
        // the previous session and a rehydration of the next
        let cfg = SessionConfig {
            max_state_bytes: per,
            max_sessions: 0,
            spill_dir: Some(dir.clone()),
            spill_pending_limit: 0,
            ..Default::default()
        };
        let mut spilling = SessionManager::new(model.clone(), cfg).unwrap();
        let mut reference = SessionManager::new(model.clone(), SessionConfig::default()).unwrap();

        let n_sessions = 2 + rng.below(3);
        let rounds = 2 + rng.below(3);
        for _ in 0..rounds {
            // random chunking *and* a random forced-eviction schedule:
            // each round visits the sessions in a fresh random order, so
            // which stream gets demoted under the 1-session budget (and
            // when it is pulled back) varies, while every session switch
            // is guaranteed to force a spill
            let mut order: Vec<usize> = (0..n_sessions).collect();
            rng.shuffle(&mut order);
            for s in order {
                let chunk = aa_tokens(rng, 1 + rng.below(32));
                let id = format!("u{s}");
                let a = spilling.advance(&id, &chunk).unwrap();
                let b = reference.advance(&id, &chunk).unwrap();
                assert_eq!(a.offset, b.offset);
                assert_eq!(
                    bits(&a),
                    bits(&b),
                    "session {id}: spilled path diverged from uninterrupted path"
                );
            }
        }
        // settle the background writer so the conservation law below is
        // exact (an in-flight commit/take-back would be a transient)
        spilling.sync_spills().unwrap();
        let st = spilling.stats();
        assert!(st.spills > 0, "the schedule must actually force spills");
        // every demotion is either promoted back or still in the tier
        // (parked or committed)
        assert_eq!(st.spills, st.rehydrations + st.spilled as u64);
        assert_eq!(st.evicted, 0, "with a spill tier, no context is ever destroyed");
        assert_eq!(st.spill_write_failures, 0);
        let _ = std::fs::remove_dir_all(&dir);
    });
}

#[test]
fn prop_snapshot_roundtrip_across_random_chunkings() {
    let mut mrng = Pcg64::new(7002);
    let model = Arc::new(NativeModel::synthetic(&SyntheticConfig::default(), &mut mrng));
    forall("snapshot -> bytes -> scorer resumes exactly", |rng| {
        let mut scorer = ChunkScorer::new(model.clone()).unwrap();
        for _ in 0..rng.below(4) {
            scorer.advance(&aa_tokens(rng, 1 + rng.below(40))).unwrap();
        }
        let snap = SessionSnapshot::capture("p", &scorer).unwrap();
        let mut restored = SessionSnapshot::from_bytes(&snap.to_bytes())
            .unwrap()
            .into_scorer(model.clone())
            .unwrap();
        assert_eq!(restored.tokens_seen(), scorer.tokens_seen());
        let next = aa_tokens(rng, 1 + rng.below(24));
        assert_eq!(
            bits(&scorer.advance(&next).unwrap()),
            bits(&restored.advance(&next).unwrap()),
        );
    });
}

#[test]
fn prop_corrupt_snapshots_never_restore() {
    let mut mrng = Pcg64::new(7003);
    let model = Arc::new(NativeModel::synthetic(&SyntheticConfig::default(), &mut mrng));
    forall("corruption fails loudly", |rng| {
        let mut scorer = ChunkScorer::new(model.clone()).unwrap();
        scorer.advance(&aa_tokens(rng, 8 + rng.below(24))).unwrap();
        let bytes = SessionSnapshot::capture("c", &scorer).unwrap().to_bytes();
        // random truncation
        let cut = rng.below(bytes.len());
        assert!(SessionSnapshot::from_bytes(&bytes[..cut]).is_err(), "cut at {cut}");
        // random bit flip
        let mut bad = bytes.clone();
        let pos = rng.below(bad.len());
        bad[pos] ^= 1 << rng.below(8);
        assert!(SessionSnapshot::from_bytes(&bad).is_err(), "bit flip at {pos}");
    });
}

#[test]
fn prop_delta_chain_restores_bitwise_identical_to_full() {
    let mut mrng = Pcg64::new(7007);
    let model = Arc::new(NativeModel::synthetic(&SyntheticConfig::default(), &mut mrng));
    forall("any full/delta interleaving == one full export", |rng| {
        let seed_tag = rng.below(1 << 30) as u64;
        let chain_dir = tempdir("chain", seed_tag);
        let full_dir = tempdir("chain_full", seed_tag);
        let mut mgr = SessionManager::new(model.clone(), SessionConfig::default()).unwrap();
        let n_sessions = 2 + rng.below(3);
        for s in 0..n_sessions {
            mgr.advance(&format!("u{s}"), &aa_tokens(rng, 8 + rng.below(16))).unwrap();
        }
        // seed the chain with a full export, then interleave random
        // advances with full/delta exports; each delta must write
        // exactly the sessions dirtied since the previous export (O(k))
        mgr.checkpoint_all(&chain_dir).unwrap();
        let exports = 1 + rng.below(3);
        for _ in 0..exports {
            let mut dirty: Vec<usize> = (0..n_sessions).collect();
            rng.shuffle(&mut dirty);
            dirty.truncate(rng.below(n_sessions + 1));
            dirty.sort_unstable();
            dirty.dedup();
            for &s in &dirty {
                mgr.advance(&format!("u{s}"), &aa_tokens(rng, 4 + rng.below(12))).unwrap();
            }
            if rng.below(2) == 0 {
                mgr.checkpoint_all(&chain_dir).unwrap();
            } else {
                let d = mgr.checkpoint_delta(&chain_dir).unwrap();
                assert_eq!(
                    (d.written, d.retained),
                    (dirty.len(), n_sessions - dirty.len()),
                    "delta must write exactly the dirty set"
                );
            }
        }
        // the chain's final state must restore bitwise identical to one
        // fresh full export of the same live sessions
        mgr.checkpoint_all(&full_dir).unwrap();
        let mut from_chain = SessionManager::new(model.clone(), SessionConfig::default()).unwrap();
        let mut from_full = SessionManager::new(model.clone(), SessionConfig::default()).unwrap();
        assert_eq!(from_chain.restore_from(&chain_dir).unwrap(), n_sessions);
        assert_eq!(from_full.restore_from(&full_dir).unwrap(), n_sessions);
        for s in 0..n_sessions {
            let id = format!("u{s}");
            let next = aa_tokens(rng, 1 + rng.below(16));
            assert_eq!(
                bits(&from_chain.advance(&id, &next).unwrap()),
                bits(&from_full.advance(&id, &next).unwrap()),
                "delta-chain restore diverged for '{id}'"
            );
        }
        let _ = std::fs::remove_dir_all(&chain_dir);
        let _ = std::fs::remove_dir_all(&full_dir);
    });
}

#[test]
fn coordinator_delta_checkpoint_is_a_barrier_and_restores() {
    let mut mrng = Pcg64::new(7008);
    let model = Arc::new(NativeModel::synthetic(&SyntheticConfig::default(), &mut mrng));
    let dir = tempdir("coord_delta", 0);
    let mut rng = Pcg64::new(9);
    let chunks: Vec<Vec<u8>> = (0..3).map(|_| aa_tokens(&mut rng, 20)).collect();

    let mut coord = Coordinator::new(EngineHandle::disconnected("artifacts"));
    coord.start_stream_pool("native", model.clone(), SessionConfig::default()).unwrap();
    for (s, c) in chunks.iter().enumerate() {
        coord.stream_chunk("native", &format!("u{s}"), c.clone()).unwrap();
    }
    // first delta into an empty dir writes everything...
    assert_eq!(coord.checkpoint_delta("native", &dir).unwrap(), 3);
    // ...an untouched second delta writes nothing
    assert_eq!(coord.checkpoint_delta("native", &dir).unwrap(), 0);
    // one session advances; only it is re-snapshotted
    coord.stream_chunk("native", "u1", chunks[1].clone()).unwrap();
    assert_eq!(coord.checkpoint_delta("native", &dir).unwrap(), 1);
    coord.shutdown();

    let mut replica = Coordinator::new(EngineHandle::disconnected("artifacts"));
    replica.start_stream_pool("native", model, SessionConfig::default()).unwrap();
    assert_eq!(replica.restore_from("native", &dir).unwrap(), 3);
    let resp = replica.stream_chunk("native", "u1", chunks[2].clone()).unwrap();
    assert_eq!(resp.scores.unwrap().offset, 40, "u1 resumes after both its chunks");
    replica.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupt_manifest_blocks_restore() {
    let mut mrng = Pcg64::new(7004);
    let model = Arc::new(NativeModel::synthetic(&SyntheticConfig::default(), &mut mrng));
    let dir = tempdir("manifest", 0);
    let mut rng = Pcg64::new(1);

    let mut donor = SessionManager::new(model.clone(), SessionConfig::default()).unwrap();
    donor.advance("a", &aa_tokens(&mut rng, 16)).unwrap();
    donor.checkpoint_all(&dir).unwrap();

    // garbage manifest: restore must fail loudly
    let manifest = dir.join("manifest.json");
    let good = std::fs::read(&manifest).unwrap();
    std::fs::write(&manifest, b"{definitely not json").unwrap();
    let mut replica = SessionManager::new(model.clone(), SessionConfig::default()).unwrap();
    assert!(replica.restore_from(&dir).is_err());
    assert!(replica.is_empty(), "a failed restore must adopt nothing");

    // a manifest lying about the snapshot's checksum is caught too
    let lying = String::from_utf8(good.clone())
        .unwrap()
        .replacen("\"crc\":", "\"crc\":1e9,\"crc_old\":", 1);
    std::fs::write(&manifest, lying).unwrap();
    assert!(replica.restore_from(&dir).is_err());
    assert!(replica.is_empty());

    // intact manifest restores fine
    std::fs::write(&manifest, &good).unwrap();
    assert_eq!(replica.restore_from(&dir).unwrap(), 1);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn coordinator_checkpoint_restart_restore_reproduces_scores() {
    let mut mrng = Pcg64::new(7005);
    let model = Arc::new(NativeModel::synthetic(&SyntheticConfig::default(), &mut mrng));
    let dir = tempdir("coord", 0);
    let mut rng = Pcg64::new(5);
    let streams: Vec<Vec<Vec<u8>>> = (0..3)
        .map(|_| (0..4).map(|_| aa_tokens(&mut rng, 24)).collect())
        .collect();

    // uninterrupted run: all 4 chunks per session in one coordinator
    let mut full_scores: Vec<Vec<u32>> = Vec::new();
    {
        let mut coord = Coordinator::new(EngineHandle::disconnected("artifacts"));
        coord.start_stream_pool("native", model.clone(), SessionConfig::default()).unwrap();
        for c in 0..4 {
            for (s, stream) in streams.iter().enumerate() {
                let resp =
                    coord.stream_chunk("native", &format!("u{s}"), stream[c].clone()).unwrap();
                full_scores.push(bits(&resp.scores.unwrap()));
            }
        }
        coord.shutdown();
    }

    // interrupted run: 2 chunks, checkpoint_all, coordinator torn down,
    // a fresh one restores and serves the remaining 2 chunks
    let mut split_scores: Vec<Vec<u32>> = Vec::new();
    {
        let mut coord = Coordinator::new(EngineHandle::disconnected("artifacts"));
        coord.start_stream_pool("native", model.clone(), SessionConfig::default()).unwrap();
        for c in 0..2 {
            for (s, stream) in streams.iter().enumerate() {
                let resp =
                    coord.stream_chunk("native", &format!("u{s}"), stream[c].clone()).unwrap();
                split_scores.push(bits(&resp.scores.unwrap()));
            }
        }
        assert_eq!(coord.checkpoint_all("native", &dir).unwrap(), 3);
        coord.shutdown();
    }
    {
        let mut coord = Coordinator::new(EngineHandle::disconnected("artifacts"));
        coord.start_stream_pool("native", model.clone(), SessionConfig::default()).unwrap();
        assert_eq!(coord.restore_from("native", &dir).unwrap(), 3);
        for c in 2..4 {
            for (s, stream) in streams.iter().enumerate() {
                let resp =
                    coord.stream_chunk("native", &format!("u{s}"), stream[c].clone()).unwrap();
                let scores = resp.scores.unwrap();
                assert_eq!(scores.offset, c * 24, "restored session resumes mid-stream");
                split_scores.push(bits(&scores));
            }
        }
        // restoring again over the live sessions must refuse
        assert!(coord.restore_from("native", &dir).is_err());
        coord.shutdown();
    }
    assert_eq!(
        full_scores, split_scores,
        "checkpoint + restart + restore must reproduce the uninterrupted run exactly"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn checkpointer_rejects_wrong_model_on_load() {
    let mut rng = Pcg64::new(7006);
    let big = Arc::new(NativeModel::synthetic(&SyntheticConfig::default(), &mut rng));
    let small = Arc::new(NativeModel::synthetic(
        &SyntheticConfig { d_model: 16, n_heads: 2, n_features: 8, ..Default::default() },
        &mut rng,
    ));
    let dir = tempdir("fingerprint", 0);
    let mut ck = Checkpointer::create(&dir).unwrap();
    let mut scorer = ChunkScorer::new(big).unwrap();
    scorer.advance(&aa_tokens(&mut rng, 12)).unwrap();
    ck.save("s", &scorer).unwrap();
    assert!(Checkpointer::open(&dir).unwrap().load("s", &small).is_err());
    let _ = std::fs::remove_dir_all(&dir);
}
