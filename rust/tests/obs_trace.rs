//! End-to-end span-tracing test: drive a budgeted [`SessionManager`]
//! through forced spill/rehydrate churn with tracing enabled, then
//! assert the drained spans render as a loadable Chrome-trace document
//! whose begin/end events balance and nest on every thread, covering
//! the whole pipeline (advance → wave → forward → spill → rehydrate).

use std::sync::{Arc, Mutex};

use performer::jsonx::Json;
use performer::obs::export::{chrome_trace, validate_chrome_trace};
use performer::obs::trace;
use performer::protein::{Corpus, CorpusConfig};
use performer::rng::Pcg64;
use performer::stream::{SessionConfig, SessionManager};
use performer::train::{NativeModel, SyntheticConfig};

// tracing is process-global: serialize the tests that toggle it
static LOCK: Mutex<()> = Mutex::new(());

fn tempdir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("obs_trace_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

#[test]
fn forced_churn_produces_a_balanced_loadable_trace() {
    let _g = LOCK.lock().unwrap();
    let mut rng = Pcg64::new(3);
    let model = Arc::new(NativeModel::synthetic(&SyntheticConfig::default(), &mut rng));
    let corpus = Corpus::generate(CorpusConfig::default());
    let per = SessionManager::new(model.clone(), SessionConfig::default())
        .unwrap()
        .per_session_bytes();
    let dir = tempdir("churn");
    let cfg = SessionConfig {
        // a one-session budget: every session switch spills the previous
        // stream and rehydrates the next
        max_state_bytes: per,
        max_sessions: 0,
        spill_dir: Some(dir.clone()),
        spill_pending_limit: 0,
        ..Default::default()
    };

    let _ = trace::drain(); // shed anything an earlier test left behind
    trace::set_enabled(true);
    {
        let mut mgr = SessionManager::new(model, cfg).unwrap();
        for round in 0..3 {
            for id in ["a", "b"] {
                let toks = corpus.concat_stream(24, 1, &mut rng).pop().unwrap();
                mgr.advance(id, &toks).unwrap();
            }
            if round == 1 {
                // settle the write-back queue mid-run so later
                // rehydrations exercise the committed-read path, not
                // just the pending take-back
                mgr.sync_spills().unwrap();
            }
        }
        let st = mgr.stats();
        assert!(st.spills > 0 && st.rehydrations > 0, "churn must actually happen: {st:?}");
        // dropping the manager joins the background writer, so its
        // spill_write spans are closed before the drain below
    }
    trace::set_enabled(false);

    let traces = trace::drain();
    let doc = chrome_trace(&traces);
    // validate the serialized form, exactly as the CI smoke will
    let parsed = Json::parse(&doc.to_string()).unwrap();
    let summary = validate_chrome_trace(&parsed).unwrap();
    assert!(summary.spans > 0, "churn with tracing on must record spans");
    assert!(summary.threads >= 2, "serving and writer threads both trace: {summary:?}");

    let names: std::collections::BTreeSet<&str> =
        traces.iter().flat_map(|t| t.events.iter().map(|e| e.name)).collect();
    for want in [
        "advance_batch",
        "wave",
        "forward_chunk_batch",
        "layer",
        "spill_enqueue",
        "rehydrate",
        "spill_write",
    ] {
        assert!(names.contains(want), "expected a '{want}' span; saw {names:?}");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn disabled_tracing_stays_silent_through_the_full_pipeline() {
    let _g = LOCK.lock().unwrap();
    trace::set_enabled(false);
    let mut rng = Pcg64::new(4);
    let model = Arc::new(NativeModel::synthetic(&SyntheticConfig::default(), &mut rng));
    let corpus = Corpus::generate(CorpusConfig::default());
    let _ = trace::drain();
    let mut mgr = SessionManager::new(model, SessionConfig::default()).unwrap();
    for _ in 0..2 {
        let toks = corpus.concat_stream(16, 1, &mut rng).pop().unwrap();
        mgr.advance("quiet", &toks).unwrap();
    }
    let events: usize = trace::drain().iter().map(|t| t.events.len()).sum();
    assert_eq!(events, 0, "instrumentation must record nothing while disabled");
}
