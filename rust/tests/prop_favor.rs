//! Property-based tests over the native FAVOR implementation (proptest
//! is not in the offline registry, so we use a seeded-generator runner
//! with failure reporting by seed — rerun any failure with the printed
//! seed).
//!
//! Invariants checked across random shapes/data:
//!   * linear-time FAVOR == quadratic materialization (both directions)
//!   * causality of the unidirectional variant
//!   * attention rows are convex weights for nonnegative features
//!   * error decreases monotonically in expectation with M
//!   * ORF projections stay orthogonal per block for every mechanism
//!   * the one-hot-V probe reconstructs the attention matrix

use performer::favor::{
    attention_matrix_favor, favor_attention, favor_bidirectional, favor_unidirectional,
    Direction, FeatureKind, FeatureMap,
};
use performer::favor::linear::favor_attention_quadratic;
use performer::linalg::{projection_matrix, OrfMechanism};
use performer::rng::Pcg64;
use performer::tensor::Mat;

const CASES: u64 = 25;

/// Tiny property-test harness: runs `f` across seeded cases, panics with
/// the failing seed for reproduction.
fn forall(name: &str, f: impl Fn(&mut Pcg64)) {
    for seed in 0..CASES {
        let mut rng = Pcg64::new(0xfeed ^ seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            panic!("property '{name}' failed at seed {seed}: {e:?}");
        }
    }
}

fn rand_dims(rng: &mut Pcg64) -> (usize, usize, usize) {
    let l = [8, 16, 24, 48, 64][rng.below(5)];
    let d = [2, 4, 8][rng.below(3)];
    let m = [4, 8, 16, 32][rng.below(4)];
    (l, d, m)
}

fn rand_mat(rng: &mut Pcg64, r: usize, c: usize, scale: f32) -> Mat {
    Mat::from_vec(r, c, rng.gaussian_vec(r * c).iter().map(|v| v * scale).collect())
}

#[test]
fn prop_linear_equals_quadratic_bidirectional() {
    forall("linear == quadratic (bid)", |rng| {
        let (l, d, m) = rand_dims(rng);
        let fm = FeatureMap::sample(FeatureKind::Relu, m, d, OrfMechanism::Regular, rng);
        let qp = fm.apply(&rand_mat(rng, l, d, 0.5));
        let kp = fm.apply(&rand_mat(rng, l, d, 0.5));
        let v = rand_mat(rng, l, d, 1.0);
        let lin = favor_bidirectional(&qp, &kp, &v);
        let quad = favor_attention_quadratic(&qp, &kp, &v, Direction::Bidirectional);
        assert!(lin.max_abs_diff(&quad) < 1e-3, "diff {}", lin.max_abs_diff(&quad));
    });
}

#[test]
fn prop_linear_equals_quadratic_unidirectional() {
    forall("linear == quadratic (uni)", |rng| {
        let (l, d, m) = rand_dims(rng);
        let fm = FeatureMap::sample(FeatureKind::Relu, m, d, OrfMechanism::Regular, rng);
        let qp = fm.apply(&rand_mat(rng, l, d, 0.5));
        let kp = fm.apply(&rand_mat(rng, l, d, 0.5));
        let v = rand_mat(rng, l, d, 1.0);
        let lin = favor_unidirectional(&qp, &kp, &v);
        let quad = favor_attention_quadratic(&qp, &kp, &v, Direction::Unidirectional);
        assert!(lin.max_abs_diff(&quad) < 1e-3, "diff {}", lin.max_abs_diff(&quad));
    });
}

#[test]
fn prop_causality() {
    forall("causality", |rng| {
        let (l, d, m) = rand_dims(rng);
        if l < 4 {
            return;
        }
        let fm = FeatureMap::sample(FeatureKind::Relu, m, d, OrfMechanism::Regular, rng);
        let q = rand_mat(rng, l, d, 0.5);
        let mut k = rand_mat(rng, l, d, 0.5);
        let mut v = rand_mat(rng, l, d, 1.0);
        let cut = 1 + rng.below(l - 2);
        let before = favor_attention(&fm, &q, &k, &v, Direction::Unidirectional);
        // perturb strictly-future rows
        for i in cut + 1..l {
            for j in 0..d {
                *k.at_mut(i, j) += 3.0;
                *v.at_mut(i, j) -= 3.0;
            }
        }
        let after = favor_attention(&fm, &q, &k, &v, Direction::Unidirectional);
        let prefix_diff = before
            .rows_slice(0, cut + 1)
            .max_abs_diff(&after.rows_slice(0, cut + 1));
        assert!(prefix_diff < 1e-6, "future leaked into prefix: {prefix_diff}");
    });
}

#[test]
fn prop_rows_are_convex_combinations() {
    forall("convex combination", |rng| {
        let (l, d, m) = rand_dims(rng);
        let fm = FeatureMap::sample(FeatureKind::Relu, m, d, OrfMechanism::Regular, rng);
        let q = rand_mat(rng, l, d, 0.8);
        let k = rand_mat(rng, l, d, 0.8);
        let v = rand_mat(rng, l, d, 1.0);
        let out = favor_attention(&fm, &q, &k, &v, Direction::Bidirectional);
        for c in 0..d {
            let lo = (0..l).map(|r| v.at(r, c)).fold(f32::INFINITY, f32::min);
            let hi = (0..l).map(|r| v.at(r, c)).fold(f32::NEG_INFINITY, f32::max);
            for r in 0..l {
                let x = out.at(r, c);
                assert!(
                    x >= lo - 1e-2 && x <= hi + 1e-2,
                    "out[{r},{c}]={x} escapes value hull [{lo},{hi}]"
                );
            }
        }
    });
}

#[test]
fn prop_one_hot_probe_reconstructs_matrix() {
    forall("one-hot probe", |rng| {
        let (l, d, m) = rand_dims(rng);
        let fm = FeatureMap::sample(FeatureKind::Relu, m, d, OrfMechanism::Regular, rng);
        let q = rand_mat(rng, l, d, 0.5);
        let k = rand_mat(rng, l, d, 0.5);
        let direct = attention_matrix_favor(&fm, &q, &k, Direction::Bidirectional);
        let probe = favor_attention(&fm, &q, &k, &Mat::eye(l), Direction::Bidirectional);
        assert!(direct.max_abs_diff(&probe) < 1e-3);
    });
}

#[test]
fn prop_orf_blocks_orthogonal_all_mechanisms() {
    forall("ORF orthogonality", |rng| {
        let d = 8; // H-ORF needs a power of two
        for mech in [OrfMechanism::Regular, OrfMechanism::Hadamard, OrfMechanism::Givens] {
            let w = projection_matrix(d, d, mech, 1.0, false, rng);
            for i in 0..d {
                for j in 0..i {
                    let cosv = performer::tensor::dot(w.row(i), w.row(j))
                        / (performer::tensor::dot(w.row(i), w.row(i)).sqrt()
                            * performer::tensor::dot(w.row(j), w.row(j)).sqrt());
                    assert!(cosv.abs() < 1e-3, "{mech:?} rows {i},{j}: cos {cosv}");
                }
            }
        }
    });
}

#[test]
fn prop_error_decreases_with_m() {
    // expectation over seeds: mean error at M=256 < mean error at M=8
    let mut err_small = 0.0f64;
    let mut err_big = 0.0f64;
    let trials = 12;
    for s in 0..trials {
        let mut rng = Pcg64::new(2000 + s);
        let d = 8;
        let l = 24;
        let q = rand_mat(&mut rng, l, d, 0.4);
        let k = rand_mat(&mut rng, l, d, 0.4);
        let exact =
            performer::favor::attention_matrix_exact(&q, &k, Direction::Bidirectional);
        for (m, acc) in [(8usize, &mut err_small), (256, &mut err_big)] {
            let fm = FeatureMap::sample(
                FeatureKind::Softmax,
                m,
                d,
                OrfMechanism::Regular,
                &mut rng.fork(m as u64),
            );
            let approx = attention_matrix_favor(&fm, &q, &k, Direction::Bidirectional);
            *acc += performer::favor::output_error(&approx, &exact);
        }
    }
    assert!(
        err_big < err_small,
        "error must fall with M: M=8 -> {err_small}, M=256 -> {err_big}"
    );
}

#[test]
fn prop_feature_maps_finite_for_all_kinds() {
    forall("feature finiteness", |rng| {
        let (l, d, m) = rand_dims(rng);
        // the full pluggable-kernel menu, clamped exp and FAVOR+ included
        for kind in FeatureKind::ALL {
            let fm = FeatureMap::sample(kind, m, d, OrfMechanism::Regular, rng);
            let x = rand_mat(rng, l, d, 1.0);
            let phi = fm.apply(&x);
            assert!(
                phi.data.iter().all(|v| v.is_finite()),
                "{kind:?} produced non-finite features"
            );
        }
    });
}
