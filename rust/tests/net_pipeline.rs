//! The pipelined wire path, end to end over real loopback sockets:
//!
//!   * a mock server that answers in a seeded-SHUFFLED order proves the
//!     client routes every out-of-order reply to the caller that issued
//!     it (matched by frame request-id, fuzzed across rounds);
//!   * a depth-8 [`PipelinedClient`] and a one-frame [`Msg::SubmitBatch`]
//!     both reproduce the blocking client's per-token scores **bitwise**;
//!   * one failed entry inside a batch (empty chunk) answers as
//!     [`ScoreEntry::Failed`] without poisoning its neighbours or the
//!     session's subsequent chunks;
//!   * a live `admin_drain` migration under a pipelining client keeps
//!     every score bit-exact;
//!   * a [`BackendPool`] whose pooled connection dies mid-idle evicts it
//!     and retries the forward once on a fresh dial — the caller never
//!     sees the dead socket.

use std::net::TcpListener;
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;
use performer::coordinator::Coordinator;
use performer::net::{
    read_frame, write_frame, BackendPool, Client, Msg, PipelinedClient, Router, RouterMetrics,
    ScoreEntry, Server, ServerConfig,
};
use performer::obs::MetricsRegistry;
use performer::protein::Corpus;
use performer::rng::Pcg64;
use performer::runtime::EngineHandle;
use performer::stream::SessionConfig;
use performer::train::{NativeModel, SyntheticConfig};

const POOL: &str = "native";
const CHUNK: usize = 24;
const ROUNDS: usize = 6;
const SESSIONS: usize = 4;

fn model() -> Arc<NativeModel> {
    let cfg = SyntheticConfig::default();
    Arc::new(NativeModel::synthetic(&cfg, &mut Pcg64::new(0)))
}

fn coordinator() -> Result<Coordinator> {
    let mut coord = Coordinator::new(EngineHandle::disconnected(std::env::temp_dir()));
    coord.start_stream_pool(POOL, model(), SessionConfig::default())?;
    Ok(coord)
}

fn worker() -> Result<Server> {
    Server::start(Arc::new(coordinator()?), "127.0.0.1:0", ServerConfig::default())
}

/// The CLI's seeded workload: `[round][session] -> chunk tokens`.
fn schedule() -> Vec<Vec<Vec<u8>>> {
    let corpus = Corpus::generate(Default::default());
    let mut rng = Pcg64::new(42);
    (0..ROUNDS)
        .map(|_| {
            (0..SESSIONS)
                .map(|_| corpus.concat_stream(CHUNK, 1, &mut rng).pop().unwrap())
                .collect()
        })
        .collect()
}

/// Per-session `(offset, bits)` ground truth from the blocking client —
/// what every pipelined/batched path must reproduce exactly.
fn blocking_bits() -> Result<Vec<Vec<(usize, u32)>>> {
    let srv = worker()?;
    let mut client = Client::connect(&srv.local_addr().to_string())?;
    let mut bits = vec![Vec::new(); SESSIONS];
    for round in schedule() {
        for (s, tokens) in round.into_iter().enumerate() {
            let scores = client.submit(POOL, &format!("user-{s}"), &tokens)?;
            push_scores(&mut bits, s, &scores);
        }
    }
    Ok(bits)
}

fn push_scores(bits: &mut [Vec<(usize, u32)>], s: usize, scores: &performer::stream::ChunkScores) {
    for (p, lp) in scores.logprob.iter().enumerate() {
        bits[s].push((scores.offset + p, lp.to_bits()));
    }
}

/// Seeded Fisher–Yates: the shuffled completion order is reproducible.
fn shuffle<T>(items: &mut [T], rng: &mut Pcg64) {
    for i in (1..items.len()).rev() {
        items.swap(i, rng.below(i + 1));
    }
}

#[test]
fn out_of_order_replies_route_to_their_callers() -> Result<()> {
    const WAVE: usize = 8;
    const WAVES: usize = 20;

    // mock server: read a wave of frames, answer it in a seeded-random
    // order, echoing each request's id into the reply payload (offset)
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    let server = std::thread::spawn(move || -> Result<()> {
        let (mut conn, _) = listener.accept()?;
        let mut rng = Pcg64::new(0xd150_4de4);
        for _ in 0..WAVES {
            let mut wave = Vec::with_capacity(WAVE);
            for _ in 0..WAVE {
                wave.push(read_frame(&mut conn)?);
            }
            shuffle(&mut wave, &mut rng);
            for (id, msg) in wave {
                let Msg::Submit { session, .. } = msg else {
                    anyhow::bail!("mock server expected submits only");
                };
                let reply = Msg::Scores {
                    session,
                    offset: id,
                    logprob: vec![f32::from_bits(id as u32)],
                    argmax: vec![0],
                    argmax_prob: vec![0.0],
                };
                write_frame(&mut conn, id, &reply)?;
            }
        }
        Ok(())
    });

    let mut client = PipelinedClient::connect(&addr.to_string(), WAVE)?;
    for _ in 0..WAVES {
        let mut pendings = Vec::with_capacity(WAVE);
        for i in 0..WAVE {
            let msg = Msg::Submit {
                pool: POOL.into(),
                session: format!("s-{i}"),
                tokens: vec![1, 2, 3],
            };
            pendings.push((format!("s-{i}"), client.send(&msg)?));
        }
        // replies arrive shuffled; each must surface on its own handle
        for (expect_session, pending) in pendings {
            let id = pending.id();
            match pending.wait()? {
                Msg::Scores { session, offset, logprob, .. } => {
                    assert_eq!(session, expect_session, "reply for the wrong caller");
                    assert_eq!(offset, id, "request id {id} got reply {offset}");
                    assert_eq!(logprob[0].to_bits(), id as u32);
                }
                other => panic!("expected scores, got {}", other.name()),
            }
        }
    }
    drop(client);
    server.join().expect("mock server panicked")?;
    Ok(())
}

#[test]
fn pipelined_depth8_is_bitwise_identical_to_blocking() -> Result<()> {
    let baseline = blocking_bits()?;

    let srv = worker()?;
    let mut client = PipelinedClient::connect(&srv.local_addr().to_string(), 8)?;
    let mut bits = vec![Vec::new(); SESSIONS];
    for round in schedule() {
        // the whole round goes out before any reply is awaited; rounds
        // stay synchronized so each session has one chunk in flight
        let mut pendings = Vec::with_capacity(SESSIONS);
        for (s, tokens) in round.iter().enumerate() {
            let msg = Msg::Submit {
                pool: POOL.into(),
                session: format!("user-{s}"),
                tokens: tokens.clone(),
            };
            pendings.push(client.send(&msg)?);
        }
        for ((s, tokens), pending) in round.iter().enumerate().zip(pendings) {
            let scores = client.finish_submit(POOL, &format!("user-{s}"), tokens, pending)?;
            push_scores(&mut bits, s, &scores);
        }
    }
    assert_eq!(bits, baseline, "pipelining changed score bits");
    Ok(())
}

#[test]
fn submit_batch_is_bitwise_identical_to_blocking() -> Result<()> {
    let baseline = blocking_bits()?;

    let srv = worker()?;
    let mut client = Client::connect(&srv.local_addr().to_string())?;
    let mut bits = vec![Vec::new(); SESSIONS];
    for round in schedule() {
        let entries: Vec<(String, Vec<u8>)> = round
            .into_iter()
            .enumerate()
            .map(|(s, tokens)| (format!("user-{s}"), tokens))
            .collect();
        let replies = client.submit_batch(POOL, entries)?;
        assert_eq!(replies.len(), SESSIONS);
        for (s, entry) in replies.into_iter().enumerate() {
            let (sid, scores) = entry.into_chunk_scores()?;
            assert_eq!(sid, format!("user-{s}"), "batch replies out of order");
            push_scores(&mut bits, s, &scores);
        }
    }
    assert_eq!(bits, baseline, "batched submits changed score bits");
    assert!(srv.metrics().batches.get() >= ROUNDS as u64);
    assert!(srv.metrics().batch_entries.get() >= (ROUNDS * SESSIONS) as u64);
    Ok(())
}

#[test]
fn one_failed_batch_entry_does_not_poison_the_rest() -> Result<()> {
    let srv = worker()?;
    let mut client = Client::connect(&srv.local_addr().to_string())?;
    let plan = schedule();
    let good0 = plan[0][0].clone();
    let good1 = plan[0][1].clone();

    // the middle entry is an empty chunk — a per-entry error, not a
    // frame error: its neighbours must score normally
    let replies = client.submit_batch(
        POOL,
        vec![
            ("user-a".into(), good0.clone()),
            ("user-bad".into(), Vec::new()),
            ("user-b".into(), good1.clone()),
        ],
    )?;
    assert_eq!(replies.len(), 3);
    match &replies[1] {
        ScoreEntry::Failed { session, message } => {
            assert_eq!(session, "user-bad");
            assert!(message.contains("empty chunk"), "unexpected message: {message}");
        }
        other => panic!("expected the empty chunk to fail, got {:?}", other.session()),
    }
    let (sid, first) = replies[0].clone().into_chunk_scores()?;
    assert_eq!(sid, "user-a");
    assert_eq!(first.offset, 0);
    let (sid, _) = replies[2].clone().into_chunk_scores()?;
    assert_eq!(sid, "user-b");

    // the surviving sessions keep streaming: offsets advanced past the
    // first chunk, unaffected by the failed neighbour
    let second = client.submit(POOL, "user-a", &good1)?;
    assert_eq!(second.offset, good0.len());
    Ok(())
}

#[test]
fn live_drain_under_pipelining_keeps_scores_bit_exact() -> Result<()> {
    let baseline = blocking_bits()?;

    let w0 = worker()?;
    let w1 = worker()?;
    let mut router = Router::start(
        "127.0.0.1:0",
        vec![w0.local_addr().to_string(), w1.local_addr().to_string()],
    )?;
    let raddr = router.local_addr().to_string();

    let mut client = PipelinedClient::connect(&raddr, 4)?;
    let mut bits = vec![Vec::new(); SESSIONS];
    for (round_no, round) in schedule().into_iter().enumerate() {
        if round_no == ROUNDS / 2 {
            // live-migrate shard 0's sessions into shard 1 mid-soak,
            // from a second control connection while the pipelined
            // client keeps streaming the very next round
            let mut admin = Client::connect(&raddr)?;
            admin.admin_drain(POOL, 0, 1)?;
        }
        let mut pendings = Vec::with_capacity(SESSIONS);
        for (s, tokens) in round.iter().enumerate() {
            let msg = Msg::Submit {
                pool: POOL.into(),
                session: format!("user-{s}"),
                tokens: tokens.clone(),
            };
            pendings.push(client.send(&msg)?);
        }
        for ((s, tokens), pending) in round.iter().enumerate().zip(pendings) {
            let scores = client.finish_submit(POOL, &format!("user-{s}"), tokens, pending)?;
            push_scores(&mut bits, s, &scores);
        }
    }
    assert_eq!(bits, baseline, "a live drain under pipelining changed score bits");
    assert_eq!(router.metrics().drains.get(), 1);
    router.shutdown();
    Ok(())
}

#[test]
fn pool_evicts_dead_connection_and_retries_on_fresh_dial() -> Result<()> {
    // mock backend: the first connection serves exactly one round trip
    // and then hangs up; the second serves until the listener drops
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    let backend = std::thread::spawn(move || -> Result<()> {
        let (mut first, _) = listener.accept()?;
        let (id, _) = read_frame(&mut first)?;
        write_frame(&mut first, id, &Msg::Ok { affected: 1 })?;
        drop(first); // the pooled connection dies while idle

        let (mut second, _) = listener.accept()?;
        for _ in 0..2 {
            let (id, _) = read_frame(&mut second)?;
            write_frame(&mut second, id, &Msg::Ok { affected: 2 })?;
        }
        Ok(())
    });

    let registry = MetricsRegistry::new();
    let metrics = Arc::new(RouterMetrics::registered(&registry));
    let pool = BackendPool::new(4, Duration::from_secs(30), metrics.clone());
    let probe = Msg::Open { pool: POOL.into(), session: "x".into() };

    // first forward dials, succeeds, and checks the connection in
    match pool.forward(&addr, &probe) {
        Msg::Ok { affected } => assert_eq!(affected, 1),
        other => panic!("first forward failed: {}", other.name()),
    }
    // give the backend a moment to actually close the pooled socket
    std::thread::sleep(Duration::from_millis(50));

    // second forward checks out the dead connection, hits a frame
    // error, evicts it, and succeeds on a fresh dial — invisibly
    match pool.forward(&addr, &probe) {
        Msg::Ok { affected } => assert_eq!(affected, 2),
        other => panic!("forward after eviction failed: {}", other.name()),
    }
    assert!(metrics.pool_evictions.get() >= 1, "the dead connection was not evicted");
    assert_eq!(metrics.pool_dials.get(), 2, "expected exactly one retry dial");

    // the fresh connection went back into the pool and is reused
    match pool.forward(&addr, &probe) {
        Msg::Ok { affected } => assert_eq!(affected, 2),
        other => panic!("pooled reuse failed: {}", other.name()),
    }
    assert!(metrics.pool_reuses.get() >= 1);
    backend.join().expect("mock backend panicked")?;
    Ok(())
}
