//! Integration tests of the serving coordinator: concurrent clients,
//! batch fusion, response correctness and clean shutdown. Requires
//! `make artifacts`.

use std::path::PathBuf;
use std::sync::Arc;

use performer::configx::ServeConfig;
use performer::coordinator::Coordinator;
use performer::protein::vocab::{AA_BASE, BOS, EOS, MASK, N_AA};
use performer::protein::{Corpus, CorpusConfig};
use performer::rng::Pcg64;
use performer::runtime::{EngineActor, EngineHandle};
use performer::stream::SessionConfig;
use performer::train::{NativeModel, SyntheticConfig};

fn built() -> bool {
    PathBuf::from("artifacts").join("tiny_relu_bid_fwd.hlo.txt").exists()
}

fn coordinator(max_batch: usize, max_wait_ms: u64) -> (EngineActor, Coordinator) {
    let actor = EngineActor::spawn("artifacts").unwrap();
    let mut coord = Coordinator::new(actor.handle());
    let cfg = ServeConfig {
        artifact: "tiny_relu_bid".into(),
        max_batch,
        max_wait_ms,
        workers: 1,
        seed: 0,
    };
    coord.start_pool(&cfg, None).unwrap();
    (actor, coord)
}

#[test]
fn fill_mask_predicts_only_masked_positions() {
    if !built() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let (_actor, mut coord) = coordinator(4, 2);
    let mut tokens = vec![BOS];
    tokens.extend([AA_BASE, AA_BASE + 1, MASK, AA_BASE + 3, MASK]);
    tokens.push(EOS);
    let resp = coord.fill_mask("tiny_relu_bid", tokens.clone()).unwrap();
    let masked: Vec<usize> =
        tokens.iter().enumerate().filter(|(_, &t)| t == MASK).map(|(i, _)| i).collect();
    assert_eq!(resp.predictions.len(), masked.len());
    for ((pos, tok, p), want_pos) in resp.predictions.iter().zip(&masked) {
        assert_eq!(pos, want_pos);
        assert!(*tok >= AA_BASE && (*tok as usize) < AA_BASE as usize + N_AA,
                "must predict an amino acid");
        assert!(*p > 0.0 && *p <= 1.0);
    }
    // non-masked positions untouched
    for (i, &t) in tokens.iter().enumerate() {
        if t != MASK {
            assert_eq!(resp.filled[i], t);
        }
    }
    coord.shutdown();
}

#[test]
fn concurrent_clients_all_get_answers() {
    if !built() {
        return;
    }
    let (_actor, coord) = coordinator(4, 3);
    let coord = Arc::new(coord);
    let corpus = Arc::new(Corpus::generate(CorpusConfig::default()));
    let mut handles = Vec::new();
    for c in 0..3u64 {
        let coord = coord.clone();
        let corpus = corpus.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = Pcg64::new(c);
            for _ in 0..8 {
                let (_, seq) = corpus.sample_iid(&mut rng);
                let mut toks = corpus.window(&seq, 64);
                toks[5] = MASK;
                let resp = coord.fill_mask("tiny_relu_bid", toks).unwrap();
                assert_eq!(resp.predictions.len(), 1);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let m = coord.metrics("tiny_relu_bid").unwrap();
    assert_eq!(m.requests.get(), 24);
    // dynamic batching must have fused at least some requests
    assert!(m.mean_batch_size() >= 1.0);
}

#[test]
fn batching_fuses_under_load() {
    if !built() {
        return;
    }
    let (_actor, coord) = coordinator(4, 25);
    let corpus = Corpus::generate(CorpusConfig::default());
    let mut rng = Pcg64::new(9);
    // submit a burst before any can complete: expect fused batches
    let mut pending = Vec::new();
    for _ in 0..12 {
        let (_, seq) = corpus.sample_iid(&mut rng);
        let mut toks = corpus.window(&seq, 64);
        toks[3] = MASK;
        pending.push(coord.submit("tiny_relu_bid", toks).unwrap());
    }
    for rx in pending {
        rx.recv().unwrap();
    }
    let m = coord.metrics("tiny_relu_bid").unwrap();
    assert!(
        m.mean_batch_size() > 1.5,
        "burst should fuse into batches, got mean {}",
        m.mean_batch_size()
    );
}

#[test]
fn stream_pool_metrics_survive_parallel_hammering() {
    // synthetic stack + disconnected engine: no artifacts needed, so
    // this concurrency test runs everywhere
    let model =
        Arc::new(NativeModel::synthetic(&SyntheticConfig::default(), &mut Pcg64::new(1)));
    let mut coord = Coordinator::new(EngineHandle::disconnected("artifacts"));
    coord.start_stream_pool("pool", model, SessionConfig::default()).unwrap();
    let coord = Arc::new(coord);
    let corpus = Arc::new(Corpus::generate(CorpusConfig::default()));

    const THREADS: u64 = 4;
    const CHUNKS: usize = 6;
    const CHUNK_LEN: usize = 32;
    let mut handles = Vec::new();
    for t in 0..THREADS {
        let coord = coord.clone();
        let corpus = corpus.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = Pcg64::new(100 + t);
            let id = format!("s{t}");
            for c in 0..CHUNKS {
                let toks = corpus.concat_stream(CHUNK_LEN, 1, &mut rng).pop().unwrap();
                let resp = coord.submit_chunk("pool", &id, toks).unwrap().recv().unwrap();
                assert!(resp.error.is_none(), "chunk {c}: {:?}", resp.error);
                let scores = resp.scores.expect("chunk response carries scores");
                assert_eq!(scores.offset, c * CHUNK_LEN);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }

    // every submission must be accounted exactly once under contention
    let m = coord.stream_metrics("pool").unwrap();
    let want = THREADS * CHUNKS as u64;
    assert_eq!(m.requests.get(), want);
    assert_eq!(m.tokens.get(), want * CHUNK_LEN as u64);
    assert_eq!(m.latency_histogram().count(), want);
    assert!(m.mean_batch_size() >= 1.0);
    assert_eq!(m.errors.get(), 0);
    // the pool's series live on the coordinator's shared registry
    let names = coord.registry().names();
    assert!(names.iter().any(|n| n == "stream_pool_requests_total"), "{names:?}");
    assert!(names.iter().any(|n| n == "persist_pool_pending_spill_bytes"), "{names:?}");
}

#[test]
fn unknown_model_is_an_error() {
    if !built() {
        return;
    }
    let (_actor, coord) = coordinator(2, 1);
    assert!(coord.submit("nonexistent", vec![MASK]).is_err());
}

#[test]
fn oversized_request_is_clipped_not_crashed() {
    if !built() {
        return;
    }
    let (_actor, mut coord) = coordinator(2, 1);
    let toks = vec![MASK; 500]; // longer than compiled L=64
    let resp = coord.fill_mask("tiny_relu_bid", toks).unwrap();
    // predictions only within the compiled window…
    assert!(resp.predictions.iter().all(|(pos, _, _)| *pos < 64));
    // …and the dropped masks are reported, not silently swallowed
    assert!(!resp.complete());
    assert_eq!(resp.truncated.len(), 500 - 64);
    assert!(resp.truncated.iter().all(|&pos| pos >= 64));
    coord.shutdown();
}
