//! Property tests for the `PFRMWIRE` frame codec: seeded-random frames
//! round-trip bitwise; truncated, bit-flipped, oversized-length,
//! wrong-version and trailing-garbage frames all refuse to decode —
//! with an error, never a panic or a partial read.

use performer::net::{frame_bytes, frame_from_bytes, Msg, ScoreEntry};
use performer::rng::Pcg64;

fn rand_string(rng: &mut Pcg64, max: usize) -> String {
    let n = rng.below(max + 1);
    (0..n).map(|_| char::from(b'a' + rng.below(26) as u8)).collect()
}

fn rand_bytes(rng: &mut Pcg64, max: usize) -> Vec<u8> {
    let n = rng.below(max + 1);
    (0..n).map(|_| rng.below(256) as u8).collect()
}

fn rand_f32s(rng: &mut Pcg64, max: usize) -> Vec<f32> {
    // arbitrary bit patterns (NaNs included): the codec carries bits,
    // not values, so even a NaN must survive bit-for-bit
    let n = rng.below(max + 1);
    (0..n).map(|_| f32::from_bits(rng.next_u64() as u32)).collect()
}

fn rand_u32s(rng: &mut Pcg64, max: usize) -> Vec<u32> {
    let n = rng.below(max + 1);
    (0..n).map(|_| rng.next_u64() as u32).collect()
}

fn rand_entry(rng: &mut Pcg64) -> ScoreEntry {
    if rng.below(2) == 0 {
        ScoreEntry::Scores {
            session: rand_string(rng, 24),
            offset: rng.next_u64() >> 32,
            logprob: rand_f32s(rng, 32),
            argmax: rand_bytes(rng, 32),
            argmax_prob: rand_f32s(rng, 32),
        }
    } else {
        ScoreEntry::Failed { session: rand_string(rng, 24), message: rand_string(rng, 40) }
    }
}

fn rand_msg(rng: &mut Pcg64) -> Msg {
    match rng.below(17) {
        0 => Msg::Open { pool: rand_string(rng, 12), session: rand_string(rng, 24) },
        1 => Msg::Submit {
            pool: rand_string(rng, 12),
            session: rand_string(rng, 24),
            tokens: rand_bytes(rng, 64),
        },
        2 => Msg::Close { pool: rand_string(rng, 12), session: rand_string(rng, 24) },
        3 => Msg::FillMask { model: rand_string(rng, 12), tokens: rand_bytes(rng, 64) },
        4 => Msg::Checkpoint {
            pool: rand_string(rng, 12),
            dir: rand_string(rng, 40),
            delta: rng.below(2) == 1,
        },
        5 => Msg::Restore { pool: rand_string(rng, 12), dir: rand_string(rng, 40) },
        6 => Msg::DrainExport { pool: rand_string(rng, 12) },
        7 => Msg::RestoreBundle { pool: rand_string(rng, 12), bundle: rand_bytes(rng, 128) },
        8 => Msg::AdminDrain {
            pool: rand_string(rng, 12),
            from: rng.below(8) as u32,
            to: rng.below(8) as u32,
        },
        9 => Msg::Ok { affected: rng.next_u64() },
        10 => Msg::Scores {
            session: rand_string(rng, 24),
            offset: rng.next_u64() >> 32,
            logprob: rand_f32s(rng, 32),
            argmax: rand_bytes(rng, 32),
            argmax_prob: rand_f32s(rng, 32),
        },
        11 => Msg::Filled {
            filled: rand_bytes(rng, 48),
            positions: rand_u32s(rng, 16),
            tokens: rand_bytes(rng, 16),
            probs: rand_f32s(rng, 16),
        },
        12 => Msg::Export { sessions: rng.next_u64() >> 48, bundle: rand_bytes(rng, 128) },
        13 => Msg::RetryAfter { millis: rng.next_u64() as u32 },
        14 => Msg::SubmitBatch {
            pool: rand_string(rng, 12),
            entries: {
                let n = rng.below(5);
                (0..n).map(|_| (rand_string(rng, 24), rand_bytes(rng, 64))).collect()
            },
        },
        15 => Msg::ScoresBatch {
            entries: {
                let n = rng.below(5);
                (0..n).map(|_| rand_entry(rng)).collect()
            },
        },
        _ => Msg::Error { message: rand_string(rng, 60) },
    }
}

/// Bit patterns compare equal even where `==` would not (NaN floats),
/// so round-trip equality is checked on the re-encoded bytes.
fn assert_bitwise_roundtrip(id: u64, msg: &Msg) {
    let bytes = frame_bytes(id, msg);
    let (rid, back) = frame_from_bytes(&bytes)
        .unwrap_or_else(|e| panic!("frame for {} failed to decode: {e:#}", msg.name()));
    assert_eq!(rid, id);
    assert_eq!(frame_bytes(rid, &back), bytes, "{} re-encode differs", msg.name());
}

#[test]
fn random_frames_roundtrip_bitwise() {
    let mut rng = Pcg64::new(0x5eed_0001);
    for i in 0..500 {
        let msg = rand_msg(&mut rng);
        assert_bitwise_roundtrip(i, &msg);
    }
}

#[test]
fn every_truncation_refuses_without_panic() {
    let mut rng = Pcg64::new(7);
    for _ in 0..20 {
        let msg = rand_msg(&mut rng);
        let bytes = frame_bytes(9, &msg);
        for cut in 0..bytes.len() {
            assert!(
                frame_from_bytes(&bytes[..cut]).is_err(),
                "{cut}-byte prefix of a {}-byte {} frame decoded",
                bytes.len(),
                msg.name()
            );
        }
    }
}

#[test]
fn every_bitflip_refuses() {
    let mut rng = Pcg64::new(11);
    for _ in 0..10 {
        let msg = rand_msg(&mut rng);
        let bytes = frame_bytes(3, &msg);
        for pos in 0..bytes.len() {
            for bit in [0x01u8, 0x80u8] {
                let mut bad = bytes.clone();
                bad[pos] ^= bit;
                assert!(
                    frame_from_bytes(&bad).is_err(),
                    "flip of bit {bit:#04x} at byte {pos} in a {} frame decoded",
                    msg.name()
                );
            }
        }
    }
}

#[test]
fn oversized_length_claim_refuses_before_allocating() {
    let bytes = frame_bytes(1, &Msg::Ok { affected: 1 });
    // claim a payload far over MAX_PAYLOAD; decode must refuse on the
    // header alone (if it tried to allocate first, this test would OOM
    // long before it failed)
    let mut bad = bytes;
    bad[24..28].copy_from_slice(&u32::MAX.to_le_bytes());
    let err = frame_from_bytes(&bad).unwrap_err();
    assert!(format!("{err:#}").contains("cap"), "wrong refusal: {err:#}");
}

#[test]
fn wrong_version_and_magic_refuse() {
    let good = frame_bytes(1, &Msg::RetryAfter { millis: 1 });
    let mut wrong_version = good.clone();
    wrong_version[8..12].copy_from_slice(&2u32.to_le_bytes());
    let err = frame_from_bytes(&wrong_version).unwrap_err();
    assert!(format!("{err:#}").contains("version"), "wrong refusal: {err:#}");

    let mut wrong_magic = good;
    wrong_magic[0] = b'X';
    let err = frame_from_bytes(&wrong_magic).unwrap_err();
    assert!(format!("{err:#}").contains("magic"), "wrong refusal: {err:#}");
}

#[test]
fn trailing_garbage_refuses() {
    let mut bytes = frame_bytes(1, &Msg::Ok { affected: 0 });
    bytes.push(0);
    assert!(frame_from_bytes(&bytes).is_err());
    assert!(frame_from_bytes(&[]).is_err());
}
