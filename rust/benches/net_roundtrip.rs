//! Wire-tier bench: what serving over TCP costs versus calling the
//! coordinator in-process — and what pipelining + batching buy back.
//! One worker on loopback, the same seeded chunk schedule five ways:
//!
//!   in-process serial   one stream_chunk at a time (the old baseline)
//!   in-process fused    stream_chunks waves (the worker's fused batch)
//!   blocking TCP        depth-1 client, one round trip per chunk
//!   pipelined TCP       PipelinedClient, a round's submits in flight
//!                       together (out-of-order completion, replies
//!                       matched by request-id)
//!   batched TCP         one SubmitBatch frame per round — one round
//!                       trip feeds one fused wave
//!
//!   cargo bench --bench net_roundtrip            # full sweep
//!   cargo bench --bench net_roundtrip -- --test  # smoke mode (CI)
//!
//! Exits non-zero if any wire path changes a single score bit — the
//! transport must be invisible to the numbers. The pipelined path is
//! expected to reach >= 4x the blocking client's tokens/sec; that gate
//! is SOFT — recorded in BENCH_net.json (`pipelined_speedup_x`,
//! `target_met`) and warned about, never failing the run. Serial rows
//! report per-request latency; fused/pipelined/batched rows report
//! per-wave latency (a wave = one round of `sessions` chunks).

use std::sync::Arc;
use std::time::Instant;

use performer::benchlib::{fmt_secs, Report};
use performer::coordinator::Coordinator;
use performer::jsonx::{num, obj, s};
use performer::net::{Client, Msg, PipelinedClient, Server, ServerConfig};
use performer::protein::{Corpus, CorpusConfig};
use performer::rng::Pcg64;
use performer::runtime::EngineHandle;
use performer::stream::SessionConfig;
use performer::train::{NativeModel, SyntheticConfig};

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * p).round() as usize;
    sorted[idx]
}

fn coordinator(pool: &str) -> anyhow::Result<Coordinator> {
    let model = Arc::new(NativeModel::synthetic(&SyntheticConfig::default(), &mut Pcg64::new(0)));
    let mut coord = Coordinator::new(EngineHandle::disconnected(std::env::temp_dir()));
    coord.start_stream_pool(pool, model, SessionConfig::default())?;
    Ok(coord)
}

/// A fresh worker over a fresh coordinator — every series starts from
/// identical pool state so the bit streams are comparable.
fn worker(pool: &str) -> anyhow::Result<Server> {
    Server::start(Arc::new(coordinator(pool)?), "127.0.0.1:0", ServerConfig::default())
}

/// `[round][session] -> tokens`, identical for every path.
fn schedule(rounds: usize, sessions: usize, chunk: usize) -> Vec<Vec<Vec<u8>>> {
    let corpus = Corpus::generate(CorpusConfig::default());
    let mut rng = Pcg64::new(42);
    (0..rounds)
        .map(|_| {
            (0..sessions)
                .map(|_| corpus.concat_stream(chunk, 1, &mut rng).pop().unwrap())
                .collect()
        })
        .collect()
}

struct Series {
    /// per-sample latencies (per request or per wave — see caller)
    lat: Vec<f64>,
    /// wall-clock of the whole schedule
    total: f64,
    /// every logprob bit pattern, schedule order
    bits: Vec<u32>,
}

impl Series {
    fn stats(mut self, total_tokens: f64) -> (f64, f64, f64, Vec<u32>) {
        self.lat.sort_by(|a, b| a.total_cmp(b));
        let p50 = percentile(&self.lat, 0.50);
        let p95 = percentile(&self.lat, 0.95);
        let tps = total_tokens / self.total.max(1e-12);
        (p50, p95, tps, self.bits)
    }
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--test") || std::env::var("STREAM_SMOKE").is_ok();
    let (chunk, rounds, sessions) = if smoke {
        (64usize, 4usize, 2usize)
    } else {
        (
            env_usize("NET_CHUNK", 256),
            env_usize("NET_ROUNDS", 24),
            // 8 sessions/round = one full fused wave (STREAM_MAX_BATCH)
            env_usize("NET_SESSIONS", 8),
        )
    };
    let depth = env_usize("NET_DEPTH", 8).max(1);
    let pool = "native";
    let plan = schedule(rounds, sessions, chunk);
    let total_tokens = (rounds * sessions * chunk) as f64;

    // ---- in-process serial: coordinator driven one chunk at a time ----
    let coord = coordinator(pool)?;
    let mut ser = Series { lat: Vec::new(), total: 0.0, bits: Vec::new() };
    let t0 = Instant::now();
    for round in &plan {
        for (sid, tokens) in round.iter().enumerate() {
            let t = Instant::now();
            let resp = coord.stream_chunk(pool, &format!("user-{sid}"), tokens.clone())?;
            ser.lat.push(t.elapsed().as_secs_f64());
            let scores = resp.scores.expect("chunk response carries scores");
            ser.bits.extend(scores.logprob.iter().map(|v| v.to_bits()));
        }
    }
    ser.total = t0.elapsed().as_secs_f64();
    let (lp50, lp95, local_tps, local_bits) = ser.stats(total_tokens);

    // ---- in-process fused: whole rounds submitted as one wave ----
    let coord = coordinator(pool)?;
    let mut fus = Series { lat: Vec::new(), total: 0.0, bits: Vec::new() };
    let t0 = Instant::now();
    for round in &plan {
        let reqs: Vec<(String, Vec<u8>)> = round
            .iter()
            .enumerate()
            .map(|(sid, tokens)| (format!("user-{sid}"), tokens.clone()))
            .collect();
        let t = Instant::now();
        let resps = coord.stream_chunks(pool, reqs)?;
        fus.lat.push(t.elapsed().as_secs_f64());
        for resp in resps {
            let scores = resp.scores.expect("chunk response carries scores");
            fus.bits.extend(scores.logprob.iter().map(|v| v.to_bits()));
        }
    }
    fus.total = t0.elapsed().as_secs_f64();
    let (fp50, fp95, fused_tps, fused_bits) = fus.stats(total_tokens);
    assert_eq!(fused_bits, local_bits, "fused in-process waves changed score bits");

    // ---- blocking TCP: depth-1 client, one round trip per chunk ----
    let srv = worker(pool)?;
    let mut client = Client::connect(&srv.local_addr().to_string())?;
    let mut blk = Series { lat: Vec::new(), total: 0.0, bits: Vec::new() };
    let t0 = Instant::now();
    for round in &plan {
        for (sid, tokens) in round.iter().enumerate() {
            let t = Instant::now();
            let scores = client.submit(pool, &format!("user-{sid}"), tokens)?;
            blk.lat.push(t.elapsed().as_secs_f64());
            blk.bits.extend(scores.logprob.iter().map(|v| v.to_bits()));
        }
    }
    blk.total = t0.elapsed().as_secs_f64();
    drop(client);
    drop(srv);
    let (wp50, wp95, wire_tps, wire_bits) = blk.stats(total_tokens);
    assert_eq!(wire_bits, local_bits, "the blocking wire path changed score bits");

    // ---- pipelined TCP: a round's submits all in flight together ----
    let srv = worker(pool)?;
    let mut pc = PipelinedClient::connect(&srv.local_addr().to_string(), depth)?;
    let mut pip = Series { lat: Vec::new(), total: 0.0, bits: Vec::new() };
    let t0 = Instant::now();
    for round in &plan {
        let t = Instant::now();
        let mut pendings = Vec::with_capacity(round.len());
        for (sid, tokens) in round.iter().enumerate() {
            let msg = Msg::Submit {
                pool: pool.into(),
                session: format!("user-{sid}"),
                tokens: tokens.clone(),
            };
            pendings.push(pc.send(&msg)?);
        }
        for ((sid, tokens), pending) in round.iter().enumerate().zip(pendings) {
            let scores = pc.finish_submit(pool, &format!("user-{sid}"), tokens, pending)?;
            pip.bits.extend(scores.logprob.iter().map(|v| v.to_bits()));
        }
        pip.lat.push(t.elapsed().as_secs_f64());
    }
    pip.total = t0.elapsed().as_secs_f64();
    drop(pc);
    drop(srv);
    let (pp50, pp95, pipe_tps, pipe_bits) = pip.stats(total_tokens);
    assert_eq!(pipe_bits, local_bits, "the pipelined wire path changed score bits");

    // ---- batched TCP: one SubmitBatch frame per round ----
    let srv = worker(pool)?;
    let mut bc = Client::connect(&srv.local_addr().to_string())?;
    let mut bat = Series { lat: Vec::new(), total: 0.0, bits: Vec::new() };
    let t0 = Instant::now();
    for round in &plan {
        let entries: Vec<(String, Vec<u8>)> = round
            .iter()
            .enumerate()
            .map(|(sid, tokens)| (format!("user-{sid}"), tokens.clone()))
            .collect();
        let t = Instant::now();
        let replies = bc.submit_batch(pool, entries)?;
        bat.lat.push(t.elapsed().as_secs_f64());
        for entry in replies {
            let (_, scores) = entry.into_chunk_scores()?;
            bat.bits.extend(scores.logprob.iter().map(|v| v.to_bits()));
        }
    }
    bat.total = t0.elapsed().as_secs_f64();
    drop(bc);
    drop(srv);
    let (bp50, bp95, batch_tps, batch_bits) = bat.stats(total_tokens);
    assert_eq!(batch_bits, local_bits, "the batched wire path changed score bits");

    let mut rep = Report::new(
        &format!(
            "Wire serving paths — {sessions} session(s) x {rounds} rounds x {chunk} tokens \
             (depth {depth}; serial rows per-request, wave rows per-round)"
        ),
        &["path", "p50", "p95", "tokens_per_s"],
    );
    rep.row(vec![
        "in-process serial".into(),
        fmt_secs(lp50),
        fmt_secs(lp95),
        format!("{local_tps:.0}"),
    ]);
    rep.row(vec![
        "in-process fused".into(),
        fmt_secs(fp50),
        fmt_secs(fp95),
        format!("{fused_tps:.0}"),
    ]);
    rep.row(vec![
        "blocking TCP".into(),
        fmt_secs(wp50),
        fmt_secs(wp95),
        format!("{wire_tps:.0}"),
    ]);
    rep.row(vec![
        format!("pipelined TCP d={depth}"),
        fmt_secs(pp50),
        fmt_secs(pp95),
        format!("{pipe_tps:.0}"),
    ]);
    rep.row(vec![
        "batched TCP".into(),
        fmt_secs(bp50),
        fmt_secs(bp95),
        format!("{batch_tps:.0}"),
    ]);
    println!("{}", rep.render());

    let overhead = wp50 / lp50.max(1e-12);
    let pipe_speedup = pipe_tps / wire_tps.max(1e-12);
    let batch_speedup = batch_tps / wire_tps.max(1e-12);
    let best_speedup = pipe_speedup.max(batch_speedup);
    const TARGET_X: f64 = 4.0;
    println!(
        "wire overhead: {overhead:.2}x on p50 ({} -> {})",
        fmt_secs(lp50),
        fmt_secs(wp50)
    );
    println!(
        "vs blocking TCP: pipelined {pipe_speedup:.2}x, batched {batch_speedup:.2}x \
         (target {TARGET_X}x)"
    );
    if best_speedup < TARGET_X {
        println!(
            "WARN: best wire speedup {best_speedup:.2}x is below the {TARGET_X}x target \
             (soft gate — recorded in BENCH_net.json, not failing the run)"
        );
    }
    println!();

    let json = obj(vec![
        ("bench", s("net_roundtrip")),
        ("mode", s(if smoke { "smoke" } else { "full" })),
        ("chunk", num(chunk as f64)),
        ("rounds", num(rounds as f64)),
        ("sessions", num(sessions as f64)),
        ("depth", num(depth as f64)),
        ("inproc_p50_secs", num(lp50)),
        ("inproc_p95_secs", num(lp95)),
        ("inproc_tokens_per_s", num(local_tps)),
        ("inproc_fused_p50_secs", num(fp50)),
        ("inproc_fused_p95_secs", num(fp95)),
        ("inproc_fused_tokens_per_s", num(fused_tps)),
        ("wire_p50_secs", num(wp50)),
        ("wire_p95_secs", num(wp95)),
        ("wire_tokens_per_s", num(wire_tps)),
        ("wire_overhead_p50_x", num(overhead)),
        ("pipelined_p50_secs", num(pp50)),
        ("pipelined_p95_secs", num(pp95)),
        ("pipelined_tokens_per_s", num(pipe_tps)),
        ("pipelined_speedup_x", num(pipe_speedup)),
        ("batched_p50_secs", num(bp50)),
        ("batched_p95_secs", num(bp95)),
        ("batched_tokens_per_s", num(batch_tps)),
        ("batched_speedup_x", num(batch_speedup)),
        ("speedup_target_x", num(TARGET_X)),
        ("target_met", num(if best_speedup >= TARGET_X { 1.0 } else { 0.0 })),
    ]);
    std::fs::write("BENCH_net.json", json.to_string() + "\n")?;
    println!("wrote BENCH_net.json");
    println!("PASS: every wire path is bitwise-identical to in-process");
    Ok(())
}
