//! Wire-tier bench: what serving over TCP costs versus calling the
//! coordinator in-process. One worker on loopback, one blocking
//! client, the same seeded chunk schedule both ways — so the delta is
//! exactly the frame codec + kernel round trip, not the model.
//!
//!   cargo bench --bench net_roundtrip            # full sweep
//!   cargo bench --bench net_roundtrip -- --test  # smoke mode (CI)
//!
//! Exits non-zero if the wire path changes a single score bit — the
//! transport must be invisible to the numbers. Writes BENCH_net.json
//! (p50/p95 per-request latency and tokens/sec, both paths) for the
//! perf trajectory.

use std::sync::Arc;
use std::time::Instant;

use performer::benchlib::{fmt_secs, Report};
use performer::coordinator::Coordinator;
use performer::jsonx::{num, obj, s};
use performer::net::{Client, Server, ServerConfig};
use performer::protein::{Corpus, CorpusConfig};
use performer::rng::Pcg64;
use performer::runtime::EngineHandle;
use performer::stream::SessionConfig;
use performer::train::{NativeModel, SyntheticConfig};

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * p).round() as usize;
    sorted[idx]
}

fn coordinator(pool: &str) -> anyhow::Result<Coordinator> {
    let model = Arc::new(NativeModel::synthetic(&SyntheticConfig::default(), &mut Pcg64::new(0)));
    let mut coord = Coordinator::new(EngineHandle::disconnected(std::env::temp_dir()));
    coord.start_stream_pool(pool, model, SessionConfig::default())?;
    Ok(coord)
}

/// `[round][session] -> tokens`, identical for both paths.
fn schedule(rounds: usize, sessions: usize, chunk: usize) -> Vec<Vec<Vec<u8>>> {
    let corpus = Corpus::generate(CorpusConfig::default());
    let mut rng = Pcg64::new(42);
    (0..rounds)
        .map(|_| {
            (0..sessions)
                .map(|_| corpus.concat_stream(chunk, 1, &mut rng).pop().unwrap())
                .collect()
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--test") || std::env::var("STREAM_SMOKE").is_ok();
    let (chunk, rounds, sessions) = if smoke {
        (64usize, 4usize, 2usize)
    } else {
        (
            env_usize("NET_CHUNK", 256),
            env_usize("NET_ROUNDS", 24),
            env_usize("NET_SESSIONS", 4),
        )
    };
    let pool = "native";
    let plan = schedule(rounds, sessions, chunk);
    let total_tokens = (rounds * sessions * chunk) as f64;

    // ---- in-process baseline: coordinator driven directly ----
    let coord = coordinator(pool)?;
    let mut local_lat = Vec::with_capacity(rounds * sessions);
    let mut local_bits: Vec<u32> = Vec::new();
    let t0 = Instant::now();
    for round in &plan {
        for (sid, tokens) in round.iter().enumerate() {
            let t = Instant::now();
            let resp = coord.stream_chunk(pool, &format!("user-{sid}"), tokens.clone())?;
            local_lat.push(t.elapsed().as_secs_f64());
            let scores = resp.scores.expect("chunk response carries scores");
            local_bits.extend(scores.logprob.iter().map(|v| v.to_bits()));
        }
    }
    let local_total = t0.elapsed().as_secs_f64();

    // ---- the same schedule through a loopback TCP worker ----
    let srv = Server::start(Arc::new(coordinator(pool)?), "127.0.0.1:0", ServerConfig::default())?;
    let mut client = Client::connect(&srv.local_addr().to_string())?;
    let mut wire_lat = Vec::with_capacity(rounds * sessions);
    let mut wire_bits: Vec<u32> = Vec::new();
    let t0 = Instant::now();
    for round in &plan {
        for (sid, tokens) in round.iter().enumerate() {
            let t = Instant::now();
            let scores = client.submit(pool, &format!("user-{sid}"), tokens)?;
            wire_lat.push(t.elapsed().as_secs_f64());
            wire_bits.extend(scores.logprob.iter().map(|v| v.to_bits()));
        }
    }
    let wire_total = t0.elapsed().as_secs_f64();
    assert_eq!(wire_bits, local_bits, "the wire path changed score bits");

    local_lat.sort_by(|a, b| a.total_cmp(b));
    wire_lat.sort_by(|a, b| a.total_cmp(b));
    let (lp50, lp95) = (percentile(&local_lat, 0.50), percentile(&local_lat, 0.95));
    let (wp50, wp95) = (percentile(&wire_lat, 0.50), percentile(&wire_lat, 0.95));
    let local_tps = total_tokens / local_total.max(1e-12);
    let wire_tps = total_tokens / wire_total.max(1e-12);

    let mut rep = Report::new(
        &format!(
            "Wire round trip vs in-process — {sessions} session(s) x {rounds} rounds x \
             {chunk} tokens"
        ),
        &["path", "p50", "p95", "tokens_per_s"],
    );
    rep.row(vec![
        "in-process".into(),
        fmt_secs(lp50),
        fmt_secs(lp95),
        format!("{local_tps:.0}"),
    ]);
    rep.row(vec![
        "loopback TCP".into(),
        fmt_secs(wp50),
        fmt_secs(wp95),
        format!("{wire_tps:.0}"),
    ]);
    println!("{}", rep.render());
    println!(
        "wire overhead: {:.2}x on p50 ({} -> {})\n",
        wp50 / lp50.max(1e-12),
        fmt_secs(lp50),
        fmt_secs(wp50)
    );

    let json = obj(vec![
        ("bench", s("net_roundtrip")),
        ("mode", s(if smoke { "smoke" } else { "full" })),
        ("chunk", num(chunk as f64)),
        ("rounds", num(rounds as f64)),
        ("sessions", num(sessions as f64)),
        ("inproc_p50_secs", num(lp50)),
        ("inproc_p95_secs", num(lp95)),
        ("inproc_tokens_per_s", num(local_tps)),
        ("wire_p50_secs", num(wp50)),
        ("wire_p95_secs", num(wp95)),
        ("wire_tokens_per_s", num(wire_tps)),
        ("wire_overhead_p50_x", num(wp50 / lp50.max(1e-12))),
    ]);
    std::fs::write("BENCH_net.json", json.to_string() + "\n")?;
    println!("wrote BENCH_net.json");
    println!("PASS: loopback serving is bitwise-identical to in-process");
    Ok(())
}
