//! Durable-persistence bench: what session durability costs. Measures
//! snapshot encode/save latency, load/rehydrate latency and snapshot
//! size for one session, the end-to-end spill/rehydrate churn a
//! budget-constrained `SessionManager` pays per chunk, the **eviction
//! enqueue latency of the background spill writer vs the old blocking
//! write** (the serving thread no longer pays the fsync), **delta vs
//! full `checkpoint_all`** on N sessions with k dirty, and a full
//! `checkpoint_all` → `restore_from` migration.
//!
//!   cargo bench --bench persist_roundtrip            # full sweep
//!   cargo bench --bench persist_roundtrip -- --test  # smoke mode (CI)
//!
//! Exits non-zero if a spill/rehydrate round trip ever changes a score
//! bit, if the per-session snapshot stops being constant-size (it is
//! the FAVOR carried state — growing with stream length would mean the
//! subsystem's core claim broke), or if a delta export writes more than
//! its dirty set. Writes BENCH_persist.json for the perf trajectory.

use std::sync::Arc;
use std::time::Instant;

use performer::benchlib::{fmt_secs, Report};
use performer::jsonx::{num, obj, s};
use performer::persist::Checkpointer;
use performer::protein::{Corpus, CorpusConfig};
use performer::rng::Pcg64;
use performer::stream::{ChunkScorer, SessionConfig, SessionManager};
use performer::train::{NativeModel, SyntheticConfig};

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--test")
        || std::env::var("STREAM_SMOKE").is_ok();
    let (chunk, rounds, reps) = if smoke {
        (128usize, 2usize, 3usize)
    } else {
        (
            env_usize("PERSIST_CHUNK", 512),
            env_usize("PERSIST_ROUNDS", 8),
            env_usize("PERSIST_REPS", 20),
        )
    };
    let dir = std::env::temp_dir().join(format!("pfrm_bench_persist_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    let mut rng = Pcg64::new(0);
    let model = Arc::new(NativeModel::synthetic(&SyntheticConfig::default(), &mut rng));
    let corpus = Corpus::generate(CorpusConfig::default());

    // ---- single-session snapshot save/load latency + size ----
    let mut scorer = ChunkScorer::new(model.clone())?;
    scorer.advance(&corpus.concat_stream(chunk, 1, &mut rng).pop().unwrap())?;
    let mut ck = Checkpointer::create(&dir.join("single"))?;
    let mut save_secs = Vec::with_capacity(reps);
    let mut load_secs = Vec::with_capacity(reps);
    let mut snap_bytes = 0u64;
    for _ in 0..reps {
        let t0 = Instant::now();
        let rec = ck.save("bench", &scorer)?;
        save_secs.push(t0.elapsed().as_secs_f64());
        snap_bytes = rec.bytes;
        let t1 = Instant::now();
        let restored = ck.load("bench", &model)?;
        load_secs.push(t1.elapsed().as_secs_f64());
        assert_eq!(restored.tokens_seen(), scorer.tokens_seen());
    }
    // the snapshot must not grow as the stream does — stream more,
    // resave. The tensor payload is exactly constant; only the JSON
    // header's position counters can gain digits, so allow that jitter
    // while still catching any real (tensor-sized) growth.
    scorer.advance(&corpus.concat_stream(chunk, 1, &mut rng).pop().unwrap())?;
    let later = ck.save("bench", &scorer)?;
    assert!(
        later.bytes.abs_diff(snap_bytes) <= 64,
        "snapshot size must stay constant in streamed length ({snap_bytes} -> {} bytes)",
        later.bytes
    );
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    let (save_s, load_s) = (mean(&save_secs), mean(&load_secs));

    let mut rep = Report::new(
        &format!("Session snapshot round trip ({reps} reps, {chunk}-token chunks)"),
        &["snapshot_bytes", "save", "load", "save_MB_per_s"],
    );
    rep.row(vec![
        snap_bytes.to_string(),
        fmt_secs(save_s),
        fmt_secs(load_s),
        format!("{:.1}", snap_bytes as f64 / 1e6 / save_s.max(1e-12)),
    ]);
    println!("{}", rep.render());

    // ---- spill/rehydrate churn under a 1-session budget ----
    let per = SessionManager::new(model.clone(), SessionConfig::default())?.per_session_bytes();
    let cfg = SessionConfig {
        max_state_bytes: per,
        max_sessions: 0,
        spill_dir: Some(dir.join("spill")),
        spill_pending_limit: 0,
        ..Default::default()
    };
    let mut mgr = SessionManager::new(model.clone(), cfg)?;
    let mut reference = SessionManager::new(model.clone(), SessionConfig::default())?;
    let t0 = Instant::now();
    let mut bitwise = true;
    for _ in 0..rounds {
        for sid in 0..2 {
            let toks = corpus.concat_stream(chunk, 1, &mut rng).pop().unwrap();
            let a = mgr.advance(&format!("u{sid}"), &toks)?;
            let b = reference.advance(&format!("u{sid}"), &toks)?;
            bitwise &= a
                .logprob
                .iter()
                .zip(&b.logprob)
                .all(|(x, y)| x.to_bits() == y.to_bits());
        }
    }
    let churn_secs = t0.elapsed().as_secs_f64();
    mgr.sync_spills()?; // settle the write-back queue: exact counters
    let st = mgr.stats();
    assert!(bitwise, "spill/rehydrate changed scores");
    assert!(st.spills > 0 && st.rehydrations > 0, "churn loop must hit the spill tier");

    let mut rep = Report::new(
        &format!("Spill/rehydrate churn — 2 sessions through a 1-session budget, {rounds} rounds"),
        &["spills", "rehydrations", "ckpt_bytes", "mean_rehydrate", "tokens_per_s"],
    );
    let mean_rehydrate = st.rehydrate_nanos as f64 / 1e9 / st.rehydrations.max(1) as f64;
    rep.row(vec![
        st.spills.to_string(),
        st.rehydrations.to_string(),
        st.checkpoint_bytes.to_string(),
        fmt_secs(mean_rehydrate),
        format!("{:.0}", (2 * rounds * chunk) as f64 / churn_secs.max(1e-12)),
    ]);
    println!("{}", rep.render());

    // ---- eviction enqueue latency vs the old blocking spill write ----
    // The serving thread now pays a capture+encode (memcpy scale) per
    // eviction; the fsynced write happens on the background writer. The
    // blocking comparator is `Checkpointer::save` on the same state —
    // exactly what PR 3's eviction path executed inline.
    let enqueue_secs = st.spill_enqueue_nanos as f64 / 1e9 / st.spills.max(1) as f64;
    let write_secs = st.spill_write_nanos as f64 / 1e9 / st.spill_commits.max(1) as f64;
    let mut rep = Report::new(
        "Async spill writer — serving-thread eviction cost vs the old blocking write",
        &["spills", "commits", "cancels", "enqueue", "bg_write", "blocking_save", "speedup"],
    );
    rep.row(vec![
        st.spills.to_string(),
        st.spill_commits.to_string(),
        st.spill_cancels.to_string(),
        fmt_secs(enqueue_secs),
        fmt_secs(write_secs),
        fmt_secs(save_s),
        format!("{:.1}x", save_s / enqueue_secs.max(1e-12)),
    ]);
    println!("{}", rep.render());

    // ---- delta vs full checkpoint_all: k dirty of N sessions ----
    let n_sessions = if smoke { 4usize } else { env_usize("PERSIST_SESSIONS", 16) };
    let k_dirty = (n_sessions / 4).max(1);
    let delta_dir = dir.join("delta");
    let mut fleet = SessionManager::new(model.clone(), SessionConfig::default())?;
    for s in 0..n_sessions {
        let toks = corpus.concat_stream(chunk, 1, &mut rng).pop().unwrap();
        fleet.advance(&format!("u{s}"), &toks)?;
    }
    let t0 = Instant::now();
    let full_written = fleet.checkpoint_all(&delta_dir)?;
    let full_secs = t0.elapsed().as_secs_f64();
    for s in 0..k_dirty {
        let toks = corpus.concat_stream(chunk, 1, &mut rng).pop().unwrap();
        fleet.advance(&format!("u{s}"), &toks)?;
    }
    let t1 = Instant::now();
    let d = fleet.checkpoint_delta(&delta_dir)?;
    let delta_secs = t1.elapsed().as_secs_f64();
    assert_eq!(full_written, n_sessions);
    assert_eq!(
        (d.written, d.retained),
        (k_dirty, n_sessions - k_dirty),
        "delta must write O(k): exactly the dirty sessions"
    );
    // a delta-chain restore must match the live state bitwise
    let mut replica2 = SessionManager::new(model.clone(), SessionConfig::default())?;
    assert_eq!(replica2.restore_from(&delta_dir)?, n_sessions);
    let probe = corpus.concat_stream(chunk, 1, &mut rng).pop().unwrap();
    let a = fleet.advance("u0", &probe)?;
    let b = replica2.advance("u0", &probe)?;
    assert!(
        a.logprob.iter().zip(&b.logprob).all(|(x, y)| x.to_bits() == y.to_bits()),
        "delta-chain restore diverged from the live stream"
    );
    let mut rep = Report::new(
        &format!(
            "Incremental checkpoint_all — {n_sessions} sessions, {k_dirty} dirty \
             (delta re-snapshots only the dirty ones)"
        ),
        &["sessions", "dirty", "full", "delta", "delta_written", "delta_retained"],
    );
    rep.row(vec![
        n_sessions.to_string(),
        k_dirty.to_string(),
        fmt_secs(full_secs),
        fmt_secs(delta_secs),
        d.written.to_string(),
        d.retained.to_string(),
    ]);
    println!("{}", rep.render());

    // ---- full migration: checkpoint_all -> restore_from ----
    let export = dir.join("export");
    let t0 = Instant::now();
    let written = mgr.checkpoint_all(&export)?;
    let export_secs = t0.elapsed().as_secs_f64();
    let mut replica = SessionManager::new(model, SessionConfig::default())?;
    let t1 = Instant::now();
    let adopted = replica.restore_from(&export)?;
    let adopt_secs = t1.elapsed().as_secs_f64();
    assert_eq!((written, adopted), (2, 2), "migration must carry both sessions");
    println!(
        "migration: exported {written} session(s) in {}, adopted in {}\n",
        fmt_secs(export_secs),
        fmt_secs(adopt_secs)
    );

    let json = obj(vec![
        ("bench", s("persist_roundtrip")),
        ("mode", s(if smoke { "smoke" } else { "full" })),
        ("snapshot_bytes", num(snap_bytes as f64)),
        ("save_secs", num(save_s)),
        ("load_secs", num(load_s)),
        ("spills", num(st.spills as f64)),
        ("rehydrations", num(st.rehydrations as f64)),
        ("mean_rehydrate_secs", num(mean_rehydrate)),
        // async spill writer: what eviction costs the serving thread now
        // vs the blocking write it used to pay inline
        ("spill_enqueue_secs", num(enqueue_secs)),
        ("spill_bg_write_secs", num(write_secs)),
        ("blocking_save_secs", num(save_s)),
        ("spill_commits", num(st.spill_commits as f64)),
        ("spill_cancels", num(st.spill_cancels as f64)),
        // delta vs full export on n_sessions with k_dirty dirty
        ("delta_sessions", num(n_sessions as f64)),
        ("delta_dirty", num(k_dirty as f64)),
        ("full_export_secs", num(full_secs)),
        ("delta_export_secs", num(delta_secs)),
        ("delta_written", num(d.written as f64)),
        ("delta_retained", num(d.retained as f64)),
        ("export_secs", num(export_secs)),
        ("adopt_secs", num(adopt_secs)),
    ]);
    std::fs::write("BENCH_persist.json", json.to_string() + "\n")?;
    println!("wrote BENCH_persist.json");

    let _ = std::fs::remove_dir_all(&dir);
    println!("PASS: durability round trips are bitwise-exact and constant-size");
    Ok(())
}
