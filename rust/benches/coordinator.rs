//! Coordinator micro-benchmarks: batcher overhead vs PJRT execute cost,
//! and the latency/throughput trade-off across batching policies — the
//! L3 profile that the §Perf pass iterates on.
//!
//! Run with `cargo bench --bench coordinator`.

use std::sync::Arc;
use std::time::Instant;

use performer::benchlib::{fmt_secs, Bench, Report};
use performer::configx::ServeConfig;
use performer::coordinator::Coordinator;
use performer::protein::vocab::{AA_BASE, MASK};
use performer::protein::{Corpus, CorpusConfig};
use performer::rng::Pcg64;
use performer::runtime::EngineActor;

fn main() -> anyhow::Result<()> {
    let artifact = "tiny_relu_bid";
    let actor = EngineActor::spawn(
        std::env::var("PERFORMER_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    )?;
    let corpus = Corpus::generate(CorpusConfig::default());
    let bench = Bench { warmup: 1, samples: 5, max_total_secs: 20.0 };

    // raw PJRT execute cost for the fwd artifact (the floor)
    let handle = actor.handle();
    let meta = handle.meta(&format!("{artifact}_fwd"))?;
    let l = meta.config.max_len;
    handle.warm(&format!("{artifact}_fwd"))?;
    {
        use performer::runtime::{HostValue, Role};
        use performer::runtime::TensorFile;
        let init = TensorFile::read(
            &std::path::Path::new("artifacts").join(format!("{artifact}_init.bin")),
        )?;
        let mut inputs = Vec::new();
        for slot in &meta.inputs {
            inputs.push(match slot.role {
                Role::Tokens => HostValue::I32(vec![AA_BASE as i32; slot.elements()]),
                Role::Param => HostValue::F32(
                    init.get(&format!("param:{}", slot.name)).unwrap().1.to_vec(),
                ),
                Role::Feature => HostValue::F32(
                    init.get(&format!("feature:{}", slot.name)).unwrap().1.to_vec(),
                ),
                _ => unreachable!(),
            });
        }
        let s = bench.run("raw_pjrt_fwd", || {
            handle.exec(&format!("{artifact}_fwd"), inputs.clone()).expect("exec")
        });
        println!("raw PJRT fwd (batch={}): {}", meta.config.batch, fmt_secs(s.median()));
    }

    // batching-policy sweep: latency vs throughput
    let mut rep = Report::new(
        "Batching policy sweep (64 requests, 1 client thread pool)",
        &["max_batch", "max_wait_ms", "wall", "req/s", "mean_batch", "p99_latency"],
    );
    for (max_batch, max_wait_ms) in [(1usize, 0u64), (2, 2), (4, 2), (4, 8), (8, 8)] {
        let cfg = ServeConfig {
            artifact: artifact.into(),
            max_batch,
            max_wait_ms,
            workers: 1,
            seed: 0,
        };
        let mut coord = Coordinator::new(actor.handle());
        coord.start_pool(&cfg, None)?;
        let mut rng = Pcg64::new(42);
        // warm
        coord.fill_mask(artifact, corpus.window(&corpus.sample_iid(&mut rng).1, l))?;

        let n = 64;
        let t0 = Instant::now();
        let mut pending = Vec::new();
        for _ in 0..n {
            let (_, seq) = corpus.sample_iid(&mut rng);
            let mut toks = corpus.window(&seq, l);
            for t in toks.iter_mut() {
                if *t >= AA_BASE && rng.uniform() < 0.15 {
                    *t = MASK;
                }
            }
            pending.push(coord.submit(artifact, toks)?);
        }
        for rx in pending {
            rx.recv()?;
        }
        let wall = t0.elapsed().as_secs_f64();
        let m = coord.metrics(artifact).unwrap();
        rep.row(vec![
            max_batch.to_string(),
            max_wait_ms.to_string(),
            fmt_secs(wall),
            format!("{:.1}", n as f64 / wall),
            format!("{:.2}", m.mean_batch_size()),
            format!("{:?}", m.latency_quantile(0.99)),
        ]);
        coord.shutdown();
    }
    println!("{}", rep.render());
    rep.save_csv(std::path::Path::new("results/coordinator_bench.csv"))?;
    let _ = Arc::strong_count(&Arc::new(()));
    Ok(())
}
