//! Fig. 14 — (1) Performer wall time as layer count grows (the paper
//! shows scaling "up to even 20 layers"); (2) attention-op time/space
//! complexity comparison between standard attention and FAVOR, the
//! paper's second 2x2 panel, here as native measurements plus explicit
//! byte accounting.
//!
//! Run with `cargo bench --bench fig14_layers`.

use performer::benchlib::{fmt_secs, Bench, Report};
use performer::favor::{exact_attention, favor_attention, Direction, FeatureKind, FeatureMap};
use performer::linalg::OrfMechanism;
use performer::rng::Pcg64;
use performer::tensor::Mat;

/// A minimal multi-layer FAVOR stack: enough structure to measure layer
/// scaling of the attention component without the (layer-count-fixed)
/// MLP dominating.
fn favor_stack(layers: usize, fm: &FeatureMap, x: &Mat) -> Mat {
    let mut h = x.clone();
    for _ in 0..layers {
        let out = favor_attention(fm, &h, &h, &h, Direction::Bidirectional);
        h.add_assign(&out);
    }
    h
}

fn exact_stack(layers: usize, x: &Mat) -> Mat {
    let mut h = x.clone();
    for _ in 0..layers {
        let out = exact_attention(&h, &h, &h, Direction::Bidirectional);
        h.add_assign(&out);
    }
    h
}

fn main() -> anyhow::Result<()> {
    let bench = Bench { warmup: 1, samples: 5, max_total_secs: 30.0 };
    let d = 64;
    let l = 1024;
    let m_feats = 128;
    let mut rng = Pcg64::new(0);
    let fm = FeatureMap::sample(FeatureKind::Relu, m_feats, d, OrfMechanism::Regular, &mut rng);
    let x = Mat::from_vec(l, d, rng.gaussian_vec(l * d));

    // panel 1: layer scaling
    let mut rep = Report::new(
        &format!("Fig. 14a — layer scaling at L={l} (paper: linear in layers up to 20)"),
        &["layers", "favor", "exact", "favor_per_layer"],
    );
    for layers in [1usize, 2, 6, 12, 20] {
        let sf = bench.run(&format!("favor_{layers}l"), || favor_stack(layers, &fm, &x));
        let se = if layers <= 6 {
            fmt_secs(bench.run(&format!("exact_{layers}l"), || exact_stack(layers, &x)).median())
        } else {
            "skipped".into()
        };
        rep.row(vec![
            layers.to_string(),
            fmt_secs(sf.median()),
            se,
            fmt_secs(sf.median() / layers as f64),
        ]);
    }
    println!("{}", rep.render());
    rep.save_csv(std::path::Path::new("results/fig14_layers.csv"))?;

    // panel 2: attention-op time + space accounting across L
    let mut rep2 = Report::new(
        "Fig. 14b — attention op time & space (native, bidirectional)",
        &["L", "exact_time", "favor_time", "exact_bytes", "favor_bytes"],
    );
    for l in [256usize, 512, 1024, 2048, 4096] {
        let q = Mat::from_vec(l, d, rng.gaussian_vec(l * d));
        let k = Mat::from_vec(l, d, rng.gaussian_vec(l * d));
        let v = Mat::from_vec(l, d, rng.gaussian_vec(l * d));
        let te = if l <= 2048 {
            fmt_secs(
                bench
                    .run(&format!("exact_L{l}"), || {
                        exact_attention(&q, &k, &v, Direction::Bidirectional)
                    })
                    .median(),
            )
        } else {
            "skipped".into()
        };
        let tf = bench.run(&format!("favor_L{l}"), || {
            favor_attention(&fm, &q, &k, &v, Direction::Bidirectional)
        });
        rep2.row(vec![
            l.to_string(),
            te,
            fmt_secs(tf.median()),
            (4 * l * l).to_string(),
            (4 * (l * m_feats + m_feats * (d + 1))).to_string(),
        ]);
    }
    println!("{}", rep2.render());
    rep2.save_csv(std::path::Path::new("results/fig14_ops.csv"))?;
    Ok(())
}
