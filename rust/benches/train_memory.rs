//! Sub-linear-memory training bench: SLiM's claim is that chunked
//! forward+backward holds peak activation memory *constant* in the
//! sequence length — only the O(L/L_c) boundary prefix-sum checkpoints
//! grow, and those are orders of magnitude smaller than activations.
//!
//!   cargo bench --bench train_memory            # full sweep, chunked to 2048
//!   cargo bench --bench train_memory -- --test  # smoke mode (CI-fast)
//!
//! Drives `chunked_loss_and_grad` over a synthetic native Performer
//! stack — no artifacts, no PJRT — measuring the analytic activation
//! accounting (`MemStats`) plus wall time per step. The full-sequence
//! path (`chunk_len = 0`, one segment) is the linear-memory baseline;
//! the chunked series then trains at **4× the longest full-path
//! context** with bit-identical peak activation bytes at every length.
//! Exits non-zero if chunked peak memory grows with L, if the 4× reach
//! isn't demonstrated, or if any gradient goes non-finite. Snapshot to
//! `BENCH_train_slim.json`.

use performer::benchlib::{fmt_secs, Report};
use performer::jsonx::{arr, num, obj, s};
use performer::protein::{lm_batch, Batch};
use performer::rng::Pcg64;
use performer::train::{
    chunked_loss_and_grad, ChunkedTrainConfig, NativeModel, ParamGrads, SyntheticConfig,
};

fn random_batch(b: usize, l: usize, seed: u64) -> Batch {
    let mut rng = Pcg64::new(seed);
    let windows: Vec<Vec<u8>> = (0..b)
        .map(|_| (0..l).map(|_| (4 + rng.below(25)) as u8).collect())
        .collect();
    lm_batch(&windows, l)
}

struct Point {
    len: usize,
    chunk: usize,
    loss: f32,
    grad_max: f32,
    peak_bytes: usize,
    boundary_bytes: usize,
    segments: usize,
    secs: f64,
}

fn measure(model: &NativeModel, b: usize, len: usize, chunk: usize, seed: u64) -> Point {
    let batch = random_batch(b, len, seed);
    let cfg = ChunkedTrainConfig { chunk_len: chunk, ..ChunkedTrainConfig::default() };
    let mut grads = ParamGrads::zeros_like(model);
    let t0 = std::time::Instant::now();
    let out = chunked_loss_and_grad(model, &batch, &cfg, &mut grads).expect("loss+grad");
    let secs = t0.elapsed().as_secs_f64();
    Point {
        len,
        chunk,
        loss: out.loss,
        grad_max: grads.max_abs(),
        peak_bytes: out.mem.peak_activation_bytes,
        boundary_bytes: out.mem.boundary_state_bytes,
        segments: out.mem.segments,
        secs,
    }
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--test")
        || std::env::var("TRAIN_MEM_SMOKE").is_ok();
    // chunked max length = 4× the longest full-sequence run, the
    // headline reach of the scheme
    let (chunk, full_lens, chunked_lens): (usize, Vec<usize>, Vec<usize>) = if smoke {
        (64, vec![64, 128], vec![256, 512])
    } else {
        (128, vec![128, 256, 512], vec![512, 1024, 2048])
    };
    let b = 2;

    let model = NativeModel::synthetic(&SyntheticConfig::default(), &mut Pcg64::new(0));

    let mut rep = Report::new(
        &format!(
            "SLiM chunked training — peak activation bytes vs context length \
             (B={b}, L_c={chunk}; expect flat for chunked, linear for full)"
        ),
        &["path", "L", "segments", "peak_act_bytes", "boundary_bytes", "loss", "secs"],
    );

    let mut full_points = Vec::new();
    for &len in &full_lens {
        let p = measure(&model, b, len, 0, 1000 + len as u64);
        rep.row(vec![
            "full".into(),
            len.to_string(),
            p.segments.to_string(),
            p.peak_bytes.to_string(),
            p.boundary_bytes.to_string(),
            format!("{:.4}", p.loss),
            fmt_secs(p.secs),
        ]);
        full_points.push(p);
    }
    let mut chunked_points = Vec::new();
    for &len in &chunked_lens {
        let p = measure(&model, b, len, chunk, 1000 + len as u64);
        rep.row(vec![
            "chunked".into(),
            len.to_string(),
            p.segments.to_string(),
            p.peak_bytes.to_string(),
            p.boundary_bytes.to_string(),
            format!("{:.4}", p.loss),
            fmt_secs(p.secs),
        ]);
        chunked_points.push(p);
    }
    println!("{}", rep.render());
    rep.save_csv(std::path::Path::new("results/train_memory.csv"))?;

    let point_json = |p: &Point| {
        obj(vec![
            ("len", num(p.len as f64)),
            ("chunk", num(p.chunk as f64)),
            ("segments", num(p.segments as f64)),
            ("peak_activation_bytes", num(p.peak_bytes as f64)),
            ("boundary_state_bytes", num(p.boundary_bytes as f64)),
            ("loss", num(p.loss as f64)),
            ("secs", num(p.secs)),
        ])
    };
    let json = obj(vec![
        ("bench", s("train_slim")),
        ("mode", s(if smoke { "smoke" } else { "full" })),
        ("batch", num(b as f64)),
        ("chunk_len", num(chunk as f64)),
        ("full", arr(full_points.iter().map(point_json))),
        ("chunked", arr(chunked_points.iter().map(point_json))),
    ]);
    std::fs::write("BENCH_train_slim.json", json.to_string() + "\n")?;
    println!("wrote BENCH_train_slim.json");

    // hard claims — fail the bench if SLiM stops being sub-linear
    for p in full_points.iter().chain(&chunked_points) {
        assert!(
            p.loss.is_finite() && p.grad_max.is_finite(),
            "L={} chunk={}: non-finite loss/grads",
            p.len,
            p.chunk
        );
    }
    let full_max = full_points.last().expect("full points").len;
    let chunked_max = chunked_points.last().expect("chunked points").len;
    assert!(
        chunked_max >= 4 * full_max,
        "chunked must reach 4x the longest full-path context \
         (full {full_max}, chunked {chunked_max})"
    );
    // every chunked length divides into equal L_c chunks here, so peak
    // activation bytes must be *identical* across the whole series
    let peak0 = chunked_points[0].peak_bytes;
    assert!(
        chunked_points.iter().all(|p| p.peak_bytes == peak0),
        "chunked peak activation bytes must be flat in L: {:?}",
        chunked_points.iter().map(|p| p.peak_bytes).collect::<Vec<_>>()
    );
    // and the linear-memory baseline really is linear (sanity that the
    // accounting measures something)
    let (f0, fl) = (&full_points[0], full_points.last().expect("full points"));
    let growth = fl.peak_bytes as f64 / f0.peak_bytes as f64;
    let len_ratio = fl.len as f64 / f0.len as f64;
    assert!(
        growth > 0.5 * len_ratio,
        "full-path peak bytes should grow ~linearly with L \
         (x{growth:.2} over x{len_ratio:.0} length)"
    );
    // boundary checkpoints are the only thing allowed to grow, and they
    // stay far below the activations they replace
    for p in &chunked_points {
        assert!(
            p.boundary_bytes < p.peak_bytes,
            "L={}: boundary states ({}) should undercut peak activations ({})",
            p.len,
            p.boundary_bytes,
            p.peak_bytes
        );
    }
    println!(
        "PASS: chunked peak activation bytes flat at {peak0} up to L={chunked_max} \
         (4x the full path's {full_max}); full path grows x{growth:.1}"
    );
    Ok(())
}
