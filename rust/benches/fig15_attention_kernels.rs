//! Fig. 15 — attention-kernel timing, two tiers:
//!
//! 1. **Native kernel sweep** (always runs, no artifacts needed): every
//!    `FeatureKind` the pluggable kernel layer offers — trig softmax,
//!    FAVOR+ positive, the generalized-attention family — timed through
//!    `favor_attention` at fixed (L, d, M) against the exact softmax
//!    baseline, with the approximation error recorded alongside. Emits
//!    `BENCH_kernels.json` so CI tracks a per-kernel perf/accuracy
//!    baseline across PRs.
//! 2. **AOT train-step timing** (runs only when a PJRT engine and
//!    compiled artifacts are available): the original model-level
//!    fwd+bwd wall-time table plus the Pallas-interpret overhead
//!    quantification.
//!
//! Run with `cargo bench --bench fig15_attention_kernels`; pass
//! `-- --test` for the CI smoke mode (small L, fewer samples).

use std::path::PathBuf;
use std::sync::Arc;

use performer::benchlib::{fmt_secs, Bench, Report};
use performer::favor::{
    exact_attention, favor_attention, output_error, Direction, FeatureKind, FeatureMap,
};
use performer::jsonx::{arr, num, obj, s};
use performer::linalg::OrfMechanism;
use performer::protein::{Corpus, CorpusConfig};
use performer::rng::Pcg64;
use performer::runtime::{Engine, HostValue};
use performer::tensor::Mat;
use performer::train::{DataGen, Split, TrainState};

fn artifacts_dir() -> PathBuf {
    std::env::var("PERFORMER_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// The native sweep: every pluggable kernel at fixed (L, d, M), wall
/// time + output error vs exact softmax attention.
fn native_kernel_sweep(smoke: bool) -> anyhow::Result<()> {
    let (l, samples) = if smoke { (256usize, 2usize) } else { (env_usize("KERNEL_BENCH_L", 2048), 5) };
    let d = 16usize;
    let m = env_usize("KERNEL_BENCH_M", 128);
    let bench = Bench { warmup: 1, samples, max_total_secs: 60.0 };

    let mut rng = Pcg64::new(15);
    let q = Mat::from_vec(l, d, rng.gaussian_vec(l * d).iter().map(|v| v * 0.5).collect());
    let k = Mat::from_vec(l, d, rng.gaussian_vec(l * d).iter().map(|v| v * 0.5).collect());
    let v = Mat::from_vec(l, d, rng.gaussian_vec(l * d));
    let exact = exact_attention(&q, &k, &v, Direction::Bidirectional);
    let t_exact = bench.run("exact", || exact_attention(&q, &k, &v, Direction::Bidirectional));

    let mut rep = Report::new(
        &format!("Fig. 15 — native attention-kernel sweep (L={l}, d={d}, M={m})"),
        &["kernel", "time", "speedup_vs_exact", "out_mse_vs_exact"],
    );
    let mut json_rows = Vec::new();
    rep.row(vec![
        "exact".into(),
        fmt_secs(t_exact.median()),
        "1.0x".into(),
        "0".into(),
    ]);
    for kind in FeatureKind::ALL {
        let fm = FeatureMap::sample(kind, m, d, OrfMechanism::Regular, &mut Pcg64::new(99));
        let t = bench.run(kind.name(), || {
            favor_attention(&fm, &q, &k, &v, Direction::Bidirectional)
        });
        let out = favor_attention(&fm, &q, &k, &v, Direction::Bidirectional);
        // some GA kinds (identity) are signed estimators that can blow
        // up on softmax targets; keep the artifact valid JSON regardless
        let mse = match output_error(&out, &exact) {
            e if e.is_finite() => e,
            _ => -1.0,
        };
        rep.row(vec![
            kind.name().into(),
            fmt_secs(t.median()),
            format!("{:.1}x", t_exact.median() / t.median()),
            format!("{mse:.3e}"),
        ]);
        json_rows.push(obj(vec![
            ("kernel", s(kind.name())),
            ("secs", num(t.median())),
            ("speedup_vs_exact", num(t_exact.median() / t.median())),
            ("out_mse_vs_exact", num(mse)),
        ]));
    }
    println!("{}", rep.render());
    let _ = std::fs::create_dir_all("results");
    rep.save_csv(std::path::Path::new("results/fig15_kernels.csv"))?;

    let json = obj(vec![
        ("bench", s("attention_kernels")),
        ("smoke", performer::jsonx::Json::Bool(smoke)),
        ("L", num(l as f64)),
        ("d", num(d as f64)),
        ("M", num(m as f64)),
        ("exact_secs", num(t_exact.median())),
        ("kernels", arr(json_rows)),
    ]);
    std::fs::write("BENCH_kernels.json", json.to_string() + "\n")?;
    println!("wrote BENCH_kernels.json");
    Ok(())
}

/// The original AOT sections — only when a PJRT engine is available.
fn aot_sections(engine: &Arc<Engine>) -> anyhow::Result<()> {
    let bench = Bench { warmup: 1, samples: 5, max_total_secs: 60.0 };
    let corpus = Arc::new(Corpus::generate(CorpusConfig::default()));

    // full train-step (fwd+bwd+Adam) timing per model variant
    let mut rep = Report::new(
        "Fig. 15 — full train step (fwd+bwd+Adam) via PJRT",
        &["artifact", "L", "batch", "params", "step_time", "tokens/s"],
    );
    for tag in [
        "base_exact_bid",
        "base_perf_relu_bid",
        "base_perf_softmax_bid",
        "base_lsh_bid",
        "long_perf_relu_uni",
        "long_exact_l1_uni",
    ] {
        if !engine.exists(&format!("{tag}_train")) {
            continue;
        }
        let mut st = TrainState::new(engine.clone(), tag)?;
        let cfg = st.train_exe.meta.config.clone();
        let mut gen: DataGen = st.data_gen(corpus.clone(), 7);
        let batch = gen.next_batch(Split::Train);
        let s = bench.run(tag, || st.train_step(&batch).expect("step"));
        let tokens = (cfg.batch * cfg.max_len) as f64;
        rep.row(vec![
            tag.into(),
            cfg.max_len.to_string(),
            cfg.batch.to_string(),
            cfg.param_count.to_string(),
            fmt_secs(s.median()),
            format!("{:.0}", tokens / s.median()),
        ]);
    }
    println!("{}", rep.render());
    rep.save_csv(std::path::Path::new("results/fig15_trainstep.csv"))?;

    // Pallas-interpret overhead on old XLA: jnp-formulated vs
    // interpret-Pallas attention op, same math
    let mut rep2 = Report::new(
        "Pallas-interpret overhead on xla_extension 0.5.1 (same math, two lowerings)",
        &["L", "favor_jnp", "favor_pallas", "overhead"],
    );
    for l in [256usize, 1024] {
        let jnp_name = format!("attn_favor_fwd_L{l}");
        let pallas_name = format!("attn_favor_pallas_fwd_L{l}");
        if !engine.exists(&jnp_name) || !engine.exists(&pallas_name) {
            continue;
        }
        let time_of = |name: &str| -> anyhow::Result<f64> {
            let exe = engine.load(name)?;
            let mut rng = Pcg64::new(l as u64);
            let inputs: Vec<HostValue> = exe
                .meta
                .inputs
                .iter()
                .map(|slot| HostValue::F32(rng.gaussian_vec(slot.elements())))
                .collect();
            Ok(bench.run(name, || exe.run(&inputs).expect("exec")).median())
        };
        let tj = time_of(&jnp_name)?;
        let tp = time_of(&pallas_name)?;
        rep2.row(vec![
            l.to_string(),
            fmt_secs(tj),
            fmt_secs(tp),
            format!("{:.1}x", tp / tj),
        ]);
    }
    println!("{}", rep2.render());
    rep2.save_csv(std::path::Path::new("results/fig15_pallas_overhead.csv"))?;
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--test");
    native_kernel_sweep(smoke)?;
    match Engine::new(artifacts_dir()) {
        Ok(engine) => aot_sections(&Arc::new(engine))?,
        Err(e) => eprintln!("[fig15] PJRT engine unavailable ({e:#}); skipped AOT sections"),
    }
    Ok(())
}
