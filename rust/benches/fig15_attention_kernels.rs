//! Fig. 15 — model-level forward/backward wall time for the "Small"
//! (1, 6, 64, 64) and scaled-"Regular" configurations, Transformer vs
//! Performer, measured on the AOT train-step artifacts (the closest
//! production analogue of the paper's fwd+bwd timing), plus the
//! Pallas-interpret overhead quantification.
//!
//! Run with `cargo bench --bench fig15_attention_kernels`.

use std::path::PathBuf;

use performer::benchlib::{fmt_secs, Bench, Report};
use performer::protein::{Corpus, CorpusConfig};
use performer::rng::Pcg64;
use performer::runtime::{Engine, HostValue};
use performer::train::{DataGen, Split, TrainState};
use std::sync::Arc;

fn artifacts_dir() -> PathBuf {
    std::env::var("PERFORMER_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

fn main() -> anyhow::Result<()> {
    let bench = Bench { warmup: 1, samples: 5, max_total_secs: 60.0 };
    let engine = Arc::new(Engine::new(artifacts_dir())?);
    let corpus = Arc::new(Corpus::generate(CorpusConfig::default()));

    // full train-step (fwd+bwd+Adam) timing per model variant
    let mut rep = Report::new(
        "Fig. 15 — full train step (fwd+bwd+Adam) via PJRT",
        &["artifact", "L", "batch", "params", "step_time", "tokens/s"],
    );
    for tag in [
        "base_exact_bid",
        "base_perf_relu_bid",
        "base_perf_softmax_bid",
        "base_lsh_bid",
        "long_perf_relu_uni",
        "long_exact_l1_uni",
    ] {
        if !engine.exists(&format!("{tag}_train")) {
            continue;
        }
        let mut st = TrainState::new(engine.clone(), tag)?;
        let cfg = st.train_exe.meta.config.clone();
        let mut gen: DataGen = st.data_gen(corpus.clone(), 7);
        let batch = gen.next_batch(Split::Train);
        let s = bench.run(tag, || st.train_step(&batch).expect("step"));
        let tokens = (cfg.batch * cfg.max_len) as f64;
        rep.row(vec![
            tag.into(),
            cfg.max_len.to_string(),
            cfg.batch.to_string(),
            cfg.param_count.to_string(),
            fmt_secs(s.median()),
            format!("{:.0}", tokens / s.median()),
        ]);
    }
    println!("{}", rep.render());
    rep.save_csv(std::path::Path::new("results/fig15_trainstep.csv"))?;

    // Pallas-interpret overhead on old XLA: jnp-formulated vs
    // interpret-Pallas attention op, same math
    let mut rep2 = Report::new(
        "Pallas-interpret overhead on xla_extension 0.5.1 (same math, two lowerings)",
        &["L", "favor_jnp", "favor_pallas", "overhead"],
    );
    for l in [256usize, 1024] {
        let jnp_name = format!("attn_favor_fwd_L{l}");
        let pallas_name = format!("attn_favor_pallas_fwd_L{l}");
        if !engine.exists(&jnp_name) || !engine.exists(&pallas_name) {
            continue;
        }
        let time_of = |name: &str| -> anyhow::Result<f64> {
            let exe = engine.load(name)?;
            let mut rng = Pcg64::new(l as u64);
            let inputs: Vec<HostValue> = exe
                .meta
                .inputs
                .iter()
                .map(|slot| HostValue::F32(rng.gaussian_vec(slot.elements())))
                .collect();
            Ok(bench.run(name, || exe.run(&inputs).expect("exec")).median())
        };
        let tj = time_of(&jnp_name)?;
        let tp = time_of(&pallas_name)?;
        rep2.row(vec![
            l.to_string(),
            fmt_secs(tj),
            fmt_secs(tp),
            format!("{:.1}x", tp / tj),
        ]);
    }
    println!("{}", rep2.render());
    rep2.save_csv(std::path::Path::new("results/fig15_pallas_overhead.csv"))?;
    Ok(())
}
