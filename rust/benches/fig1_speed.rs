//! Fig. 1 — forward/backward attention speed vs sequence length L:
//! Transformer (exact) vs Performer (FAVOR) vs "X (OPT)" (identity).
//!
//! Two measurement series per point:
//!   * AOT/HLO — the attention-op artifacts executed through PJRT, i.e.
//!     exactly what the production stack runs (includes the backward
//!     pass via the *_bwd artifacts);
//!   * native — the rust FAVOR/exact implementations, isolating
//!     algorithmic scaling from XLA overheads.
//!
//! The paper's claim reproduced here is the *shape*: exact is ~quadratic
//! in L and dies early; FAVOR is ~linear and tracks the identity "OPT"
//! ceiling. Run with `cargo bench --bench fig1_speed`.

use std::path::PathBuf;

use performer::benchlib::{fmt_secs, loglog_slope, Bench, Report};
use performer::favor::{exact_attention, favor_attention, Direction, FeatureKind, FeatureMap};
use performer::linalg::OrfMechanism;
use performer::rng::Pcg64;
use performer::runtime::{Engine, HostValue};
use performer::tensor::Mat;

fn artifacts_dir() -> PathBuf {
    std::env::var("PERFORMER_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

fn main() -> anyhow::Result<()> {
    let bench = Bench { warmup: 1, samples: 5, max_total_secs: 25.0 };
    let engine = Engine::new(artifacts_dir())?;

    // --- series 1: AOT attention ops through PJRT ---------------------
    let mut rep = Report::new(
        "Fig. 1 — attention op wall time via PJRT (bh=4, d_head=64, M=128)",
        &["L", "pass", "exact", "favor", "identity(OPT)"],
    );
    let mut series: std::collections::BTreeMap<(String, String), Vec<(f64, f64)>> =
        Default::default();
    for l in [128usize, 256, 512, 1024, 2048, 4096] {
        for pass in ["fwd", "bwd"] {
            let mut cells = vec![l.to_string(), pass.to_string()];
            for mech in ["exact", "favor", "identity"] {
                let name = format!("attn_{mech}_{pass}_L{l}");
                if !engine.exists(&name) {
                    cells.push("-".into());
                    continue;
                }
                let exe = engine.load(&name)?;
                let meta = &exe.meta;
                let mut rng = Pcg64::new(l as u64);
                let inputs: Vec<HostValue> = meta
                    .inputs
                    .iter()
                    .map(|slot| HostValue::F32(rng.gaussian_vec(slot.elements())))
                    .collect();
                let s = bench.run(&name, || exe.run(&inputs).expect("exec"));
                cells.push(fmt_secs(s.median()));
                series
                    .entry((mech.into(), pass.into()))
                    .or_default()
                    .push((l as f64, s.median()));
            }
            rep.row(cells);
        }
    }
    println!("{}", rep.render());
    rep.save_csv(std::path::Path::new("results/fig1_hlo.csv"))?;

    println!("scaling exponents (log-log slope of median time vs L):");
    for ((mech, pass), pts) in &series {
        if pts.len() >= 3 {
            let xs: Vec<f64> = pts.iter().map(|p| p.0).collect();
            let ys: Vec<f64> = pts.iter().map(|p| p.1).collect();
            println!("  {mech:>8} {pass}: {:.2}", loglog_slope(&xs, &ys));
        }
    }

    // --- series 2: native implementations ------------------------------
    let d = 64;
    let mut rng = Pcg64::new(0);
    let fm = FeatureMap::sample(FeatureKind::Relu, 128, d, OrfMechanism::Regular, &mut rng);
    let mut rep2 = Report::new(
        "Fig. 1 (native series) — rust implementations, bidirectional",
        &["L", "exact", "favor", "ratio"],
    );
    let mut ls = Vec::new();
    let mut favor_t = Vec::new();
    let mut exact_t = Vec::new();
    for l in [128usize, 256, 512, 1024, 2048] {
        let q = Mat::from_vec(l, d, rng.gaussian_vec(l * d));
        let k = Mat::from_vec(l, d, rng.gaussian_vec(l * d));
        let v = Mat::from_vec(l, d, rng.gaussian_vec(l * d));
        let se = bench.run(&format!("native_exact_{l}"), || {
            exact_attention(&q, &k, &v, Direction::Bidirectional)
        });
        let sf = bench.run(&format!("native_favor_{l}"), || {
            favor_attention(&fm, &q, &k, &v, Direction::Bidirectional)
        });
        ls.push(l as f64);
        exact_t.push(se.median());
        favor_t.push(sf.median());
        rep2.row(vec![
            l.to_string(),
            fmt_secs(se.median()),
            fmt_secs(sf.median()),
            format!("{:.2}x", se.median() / sf.median()),
        ]);
    }
    println!("{}", rep2.render());
    println!(
        "native exponents: exact {:.2} (expect ~2), favor {:.2} (expect ~1)",
        loglog_slope(&ls, &exact_t),
        loglog_slope(&ls, &favor_t)
    );
    rep2.save_csv(std::path::Path::new("results/fig1_native.csv"))?;
    Ok(())
}
