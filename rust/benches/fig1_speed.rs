//! Fig. 1 — forward/backward attention speed vs sequence length L:
//! Transformer (exact) vs Performer (FAVOR) vs "X (OPT)" (identity).
//!
//! Two measurement series per point:
//!   * AOT/HLO — the attention-op artifacts executed through PJRT, i.e.
//!     exactly what the production stack runs (includes the backward
//!     pass via the *_bwd artifacts); skipped cleanly when no compiled
//!     artifacts are available (CI smoke runs);
//!   * native — the rust FAVOR/exact implementations, isolating
//!     algorithmic scaling from XLA overheads.
//!
//! Plus the dense-core microbench behind both: square matmuls with the
//! SIMD dispatch active vs pinned to the scalar kernels, recording the
//! speedup to `BENCH_fig1_speed.json`. The ≥2× AVX2 target is
//! soft-gated — recorded and warned on, never hard-failed, because CI
//! runners are too noisy for a hard wall-clock gate.
//!
//! The paper's claim reproduced here is the *shape*: exact is ~quadratic
//! in L and dies early; FAVOR is ~linear and tracks the identity "OPT"
//! ceiling. Run with `cargo bench --bench fig1_speed`
//! (`-- --test` or `FIG1_SMOKE=1` for the CI-fast smoke mode).

use std::path::PathBuf;

use performer::benchlib::{fmt_secs, loglog_slope, Bench, Report};
use performer::favor::{exact_attention, favor_attention, Direction, FeatureKind, FeatureMap};
use performer::jsonx::{arr, num, obj, s};
use performer::linalg::OrfMechanism;
use performer::rng::Pcg64;
use performer::runtime::{Engine, HostValue};
use performer::tensor::{active_level, set_level_override, Mat, SimdLevel};

fn artifacts_dir() -> PathBuf {
    std::env::var("PERFORMER_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

fn aot_series(bench: &Bench, engine: &Engine, ls: &[usize]) -> anyhow::Result<()> {
    let mut rep = Report::new(
        "Fig. 1 — attention op wall time via PJRT (bh=4, d_head=64, M=128)",
        &["L", "pass", "exact", "favor", "identity(OPT)"],
    );
    let mut series: std::collections::BTreeMap<(String, String), Vec<(f64, f64)>> =
        Default::default();
    let mut measured = 0usize;
    for &l in ls {
        for pass in ["fwd", "bwd"] {
            let mut cells = vec![l.to_string(), pass.to_string()];
            for mech in ["exact", "favor", "identity"] {
                let name = format!("attn_{mech}_{pass}_L{l}");
                if !engine.exists(&name) {
                    cells.push("-".into());
                    continue;
                }
                let exe = engine.load(&name)?;
                let meta = &exe.meta;
                let mut rng = Pcg64::new(l as u64);
                let inputs: Vec<HostValue> = meta
                    .inputs
                    .iter()
                    .map(|slot| HostValue::F32(rng.gaussian_vec(slot.elements())))
                    .collect();
                let st = bench.run(&name, || exe.run(&inputs).expect("exec"));
                cells.push(fmt_secs(st.median()));
                series
                    .entry((mech.into(), pass.into()))
                    .or_default()
                    .push((l as f64, st.median()));
                measured += 1;
            }
            rep.row(cells);
        }
    }
    if measured == 0 {
        println!("AOT series skipped: no attention artifacts compiled");
        return Ok(());
    }
    println!("{}", rep.render());
    rep.save_csv(std::path::Path::new("results/fig1_hlo.csv"))?;

    println!("scaling exponents (log-log slope of median time vs L):");
    for ((mech, pass), pts) in &series {
        if pts.len() >= 3 {
            let xs: Vec<f64> = pts.iter().map(|p| p.0).collect();
            let ys: Vec<f64> = pts.iter().map(|p| p.1).collect();
            println!("  {mech:>8} {pass}: {:.2}", loglog_slope(&xs, &ys));
        }
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let smoke =
        std::env::args().any(|a| a == "--test") || std::env::var("FIG1_SMOKE").is_ok();
    let bench = if smoke {
        Bench { warmup: 1, samples: 2, max_total_secs: 3.0 }
    } else {
        Bench { warmup: 1, samples: 5, max_total_secs: 25.0 }
    };

    // --- series 1: AOT attention ops through PJRT ---------------------
    // a missing PJRT plugin / artifacts dir must not sink the native and
    // SIMD series, which need no compiled artifacts at all
    let aot_ls: &[usize] =
        if smoke { &[128, 256] } else { &[128, 256, 512, 1024, 2048, 4096] };
    match Engine::new(artifacts_dir()) {
        Ok(engine) => aot_series(&bench, &engine, aot_ls)?,
        Err(e) => println!("AOT series skipped (engine unavailable: {e:#})"),
    }

    // --- series 2: native implementations ------------------------------
    let d = 64;
    let mut rng = Pcg64::new(0);
    let fm = FeatureMap::sample(FeatureKind::Relu, 128, d, OrfMechanism::Regular, &mut rng);
    let mut rep2 = Report::new(
        "Fig. 1 (native series) — rust implementations, bidirectional",
        &["L", "exact", "favor", "ratio"],
    );
    let native_ls: &[usize] = if smoke { &[128, 256] } else { &[128, 256, 512, 1024, 2048] };
    let mut ls = Vec::new();
    let mut favor_t = Vec::new();
    let mut exact_t = Vec::new();
    for &l in native_ls {
        let q = Mat::from_vec(l, d, rng.gaussian_vec(l * d));
        let k = Mat::from_vec(l, d, rng.gaussian_vec(l * d));
        let v = Mat::from_vec(l, d, rng.gaussian_vec(l * d));
        let se = bench.run(&format!("native_exact_{l}"), || {
            exact_attention(&q, &k, &v, Direction::Bidirectional)
        });
        let sf = bench.run(&format!("native_favor_{l}"), || {
            favor_attention(&fm, &q, &k, &v, Direction::Bidirectional)
        });
        ls.push(l as f64);
        exact_t.push(se.median());
        favor_t.push(sf.median());
        rep2.row(vec![
            l.to_string(),
            fmt_secs(se.median()),
            fmt_secs(sf.median()),
            format!("{:.2}x", se.median() / sf.median()),
        ]);
    }
    println!("{}", rep2.render());
    println!(
        "native exponents: exact {:.2} (expect ~2), favor {:.2} (expect ~1)",
        loglog_slope(&ls, &exact_t),
        loglog_slope(&ls, &favor_t)
    );
    rep2.save_csv(std::path::Path::new("results/fig1_native.csv"))?;

    // --- series 3: dense-core SIMD microbench --------------------------
    // square matmuls, dispatch active vs pinned to the scalar kernels.
    // The speedup is the SIMD-on vs SIMD-off delta the BENCH JSON tracks;
    // the ≥2× AVX2 target is soft-gated (warned, never failed) because
    // shared runners are too noisy for a hard wall-clock assert
    let level = active_level();
    let mut rep3 = Report::new(
        &format!("Dense-core matmul — SIMD dispatch ({}) vs scalar kernels", level.name()),
        &["N", "scalar", "simd", "speedup"],
    );
    let simd_ns: &[usize] = if smoke { &[256] } else { &[256, 512] };
    let mut simd_points = Vec::new();
    for &n in simd_ns {
        let a = Mat::from_vec(n, n, rng.gaussian_vec(n * n));
        let b = Mat::from_vec(n, n, rng.gaussian_vec(n * n));
        let effective = set_level_override(Some(SimdLevel::Scalar));
        assert_eq!(effective, SimdLevel::Scalar, "scalar pin must always hold");
        let s_scalar = bench.run(&format!("matmul_{n}_scalar"), || a.matmul(&b));
        set_level_override(None);
        let s_simd = bench.run(&format!("matmul_{n}_{}", level.name()), || a.matmul(&b));
        let speedup = s_scalar.median() / s_simd.median();
        rep3.row(vec![
            n.to_string(),
            fmt_secs(s_scalar.median()),
            fmt_secs(s_simd.median()),
            format!("{speedup:.2}x"),
        ]);
        simd_points.push((n, s_scalar.median(), s_simd.median(), speedup));
    }
    println!("{}", rep3.render());
    let worst = simd_points.iter().map(|p| p.3).fold(f64::INFINITY, f64::min);
    if level == SimdLevel::Scalar {
        println!("SIMD dispatch inactive (scalar build or override): speedup ~1x expected");
    } else if worst < 2.0 {
        println!(
            "WARN: SIMD matmul speedup {worst:.2}x under the 2x target at level {} \
             (recorded, soft-gated)",
            level.name()
        );
    } else {
        println!("PASS: SIMD matmul clears the 2x target ({worst:.2}x at level {})", level.name());
    }

    // perf-trajectory artifact: native scaling + SIMD on/off deltas
    let json = obj(vec![
        ("bench", s("fig1_speed")),
        ("mode", s(if smoke { "smoke" } else { "full" })),
        ("simd_level", s(level.name())),
        (
            "native",
            arr(ls.iter().zip(exact_t.iter().zip(&favor_t)).map(|(&l, (&e, &f))| {
                obj(vec![
                    ("l", num(l)),
                    ("exact_secs", num(e)),
                    ("favor_secs", num(f)),
                ])
            })),
        ),
        (
            "native_exponents",
            obj(vec![
                ("exact", num(loglog_slope(&ls, &exact_t))),
                ("favor", num(loglog_slope(&ls, &favor_t))),
            ]),
        ),
        (
            "simd_matmul",
            arr(simd_points.iter().map(|&(n, sc, si, sp)| {
                obj(vec![
                    ("n", num(n as f64)),
                    ("scalar_secs", num(sc)),
                    ("simd_secs", num(si)),
                    ("speedup", num(sp)),
                ])
            })),
        ),
    ]);
    std::fs::write("BENCH_fig1_speed.json", json.to_string() + "\n")?;
    println!("wrote BENCH_fig1_speed.json");
    Ok(())
}
