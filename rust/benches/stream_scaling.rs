//! Streaming-session scaling bench: the stream subsystem's core claim is
//! that per-chunk latency and resident state stay *constant* as the
//! total streamed length grows (8k → 256k+ tokens here), because causal
//! FAVOR carries only the M×(d+1) prefix sums per head. Exact attention
//! has no such mode at all — its per-token cost and memory grow with the
//! context.
//!
//!   cargo bench --bench stream_scaling            # full sweep, 8k→262k
//!   cargo bench --bench stream_scaling -- --test  # smoke mode (CI-fast)
//!
//! No artifacts required: drives a synthetic native Performer stack
//! through the shared `stream::sweep` measurement core. Exits non-zero
//! if per-chunk latency fails to stay flat or the resident state grows
//! with the streamed length.

use std::sync::Arc;

use performer::benchlib::{fmt_secs, loglog_slope, Report};
use performer::protein::{Corpus, CorpusConfig};
use performer::rng::Pcg64;
use performer::stream::{chunked_latency_point, sweep_totals};
use performer::train::{NativeModel, SyntheticConfig};

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--test")
        || std::env::var("STREAM_SMOKE").is_ok();
    let (chunk, totals): (usize, Vec<usize>) = if smoke {
        (256, sweep_totals(4096, 4, 16_384))
    } else {
        let chunk = env_usize("STREAM_CHUNK", 512);
        let max_total = env_usize("STREAM_MAX_TOTAL", 262_144).max(chunk);
        (chunk, sweep_totals(8192.min(max_total), 4, max_total))
    };

    let mut rng = Pcg64::new(0);
    let model = Arc::new(NativeModel::synthetic(&SyntheticConfig::default(), &mut rng));
    let corpus = Corpus::generate(CorpusConfig::default());

    let mut rep = Report::new(
        &format!(
            "Stream scaling — per-chunk latency & resident state vs total length \
             (chunk={chunk}; expect flat)"
        ),
        &["total_tokens", "chunks", "first", "last", "last/first", "state_bytes", "tokens_per_s"],
    );

    let mut xs = Vec::new();
    let mut ys = Vec::new();
    let mut state_sizes = Vec::new();
    let mut worst_ratio = 0.0f64;
    for &total in &totals {
        let p = chunked_latency_point(&model, &corpus, chunk, total, &mut rng)?;
        worst_ratio = worst_ratio.max(p.flatness_ratio());
        xs.push(total as f64);
        ys.push(p.last_secs);
        state_sizes.push(p.state_bytes);
        rep.row(vec![
            total.to_string(),
            p.n_chunks.to_string(),
            fmt_secs(p.first_secs),
            fmt_secs(p.last_secs),
            format!("{:.2}", p.flatness_ratio()),
            p.state_bytes.to_string(),
            format!("{:.0}", p.tokens_per_sec()),
        ]);
    }
    println!("{}", rep.render());

    let slope = if xs.len() > 1 { loglog_slope(&xs, &ys) } else { 0.0 };
    println!("per-chunk latency scaling exponent vs total length: {slope:.3} (0 = flat)");
    println!(
        "resident state: {} bytes at every total (constant by construction)",
        state_sizes[0]
    );
    rep.save_csv(std::path::Path::new("results/stream_scaling.csv"))?;

    // hard claims — fail the bench if streaming stops being O(1)/chunk
    assert!(
        state_sizes.iter().all(|&b| b == state_sizes[0]),
        "resident state must not grow with streamed length: {state_sizes:?}"
    );
    assert!(
        worst_ratio < 2.0,
        "per-chunk latency must stay flat within a stream (worst last/first = {worst_ratio:.2})"
    );
    assert!(
        slope.abs() < 0.25,
        "per-chunk latency must not scale with total length (slope {slope:.3})"
    );
    println!("PASS: per-chunk latency and resident state are flat in total streamed length");
    Ok(())
}
