//! Streaming-session scaling bench: the stream subsystem's core claim is
//! that per-chunk latency and resident state stay *constant* as the
//! total streamed length grows (8k → 256k+ tokens here), because causal
//! FAVOR carries only the M×(d+1) prefix sums per head. Exact attention
//! has no such mode at all — its per-token cost and memory grow with the
//! context.
//!
//!   cargo bench --bench stream_scaling            # full sweep, 8k→262k
//!   cargo bench --bench stream_scaling -- --test  # smoke mode (CI-fast)
//!
//! Also sweeps the **batched execution core**: B concurrent sessions
//! advanced one at a time vs fused through `ChunkScorer::advance_batch`
//! (one `forward_chunk_batch` per round), recording aggregate token
//! throughput to `BENCH_stream_batched.json` so the perf trajectory is
//! tracked. In full mode the B=8 fused sweep must clear 2× the
//! sequential aggregate throughput.
//!
//! No artifacts required: drives a synthetic native Performer stack
//! through the shared `stream::sweep` measurement core. Exits non-zero
//! if per-chunk latency fails to stay flat, the resident state grows
//! with the streamed length, or fused scores diverge from sequential.

use std::sync::Arc;

use performer::benchlib::{fmt_secs, loglog_slope, Report};
use performer::jsonx::{arr, num, obj, s};
use performer::protein::{Corpus, CorpusConfig};
use performer::rng::Pcg64;
use performer::stream::{chunked_latency_point, fused_throughput_point, sweep_totals};
use performer::tensor::{active_level, matmul_threads, set_level_override, SimdLevel};
use performer::train::{NativeModel, SyntheticConfig};

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--test")
        || std::env::var("STREAM_SMOKE").is_ok();
    let (chunk, totals): (usize, Vec<usize>) = if smoke {
        (256, sweep_totals(4096, 4, 16_384))
    } else {
        let chunk = env_usize("STREAM_CHUNK", 512);
        let max_total = env_usize("STREAM_MAX_TOTAL", 262_144).max(chunk);
        (chunk, sweep_totals(8192.min(max_total), 4, max_total))
    };

    let mut rng = Pcg64::new(0);
    let model = Arc::new(NativeModel::synthetic(&SyntheticConfig::default(), &mut rng));
    let corpus = Corpus::generate(CorpusConfig::default());

    let mut rep = Report::new(
        &format!(
            "Stream scaling — per-chunk latency & resident state vs total length \
             (chunk={chunk}; expect flat)"
        ),
        &["total_tokens", "chunks", "first", "last", "last/first", "state_bytes", "tokens_per_s"],
    );

    let mut xs = Vec::new();
    let mut ys = Vec::new();
    let mut state_sizes = Vec::new();
    let mut worst_ratio = 0.0f64;
    for &total in &totals {
        let p = chunked_latency_point(&model, &corpus, chunk, total, &mut rng)?;
        worst_ratio = worst_ratio.max(p.flatness_ratio());
        xs.push(total as f64);
        ys.push(p.last_secs);
        state_sizes.push(p.state_bytes);
        rep.row(vec![
            total.to_string(),
            p.n_chunks.to_string(),
            fmt_secs(p.first_secs),
            fmt_secs(p.last_secs),
            format!("{:.2}", p.flatness_ratio()),
            p.state_bytes.to_string(),
            format!("{:.0}", p.tokens_per_sec()),
        ]);
    }
    println!("{}", rep.render());

    let slope = if xs.len() > 1 { loglog_slope(&xs, &ys) } else { 0.0 };
    println!("per-chunk latency scaling exponent vs total length: {slope:.3} (0 = flat)");
    println!(
        "resident state: {} bytes at every total (constant by construction)",
        state_sizes[0]
    );
    rep.save_csv(std::path::Path::new("results/stream_scaling.csv"))?;

    // hard claims — fail the bench if streaming stops being O(1)/chunk
    assert!(
        state_sizes.iter().all(|&b| b == state_sizes[0]),
        "resident state must not grow with streamed length: {state_sizes:?}"
    );
    assert!(
        worst_ratio < 2.0,
        "per-chunk latency must stay flat within a stream (worst last/first = {worst_ratio:.2})"
    );
    assert!(
        slope.abs() < 0.25,
        "per-chunk latency must not scale with total length (slope {slope:.3})"
    );
    println!("PASS: per-chunk latency and resident state are flat in total streamed length");

    // ---- batched execution core: fused vs sequential session advance ----
    let (fused_chunk, n_chunks, sessions): (usize, usize, Vec<usize>) = if smoke {
        (128, 2, vec![2, 8])
    } else {
        (
            env_usize("STREAM_FUSED_CHUNK", 512),
            env_usize("STREAM_FUSED_CHUNKS", 8),
            vec![1, 2, 4, 8],
        )
    };
    let mut rep = Report::new(
        &format!(
            "Fused multi-session advance — aggregate throughput vs sequential \
             (chunk={fused_chunk}, {n_chunks} chunks/session, {} threads)",
            matmul_threads()
        ),
        &["sessions", "seq_tok_per_s", "fused_tok_per_s", "speedup", "max_diff"],
    );
    let mut points = Vec::new();
    for &b in &sessions {
        let p = fused_throughput_point(&model, &corpus, b, fused_chunk, n_chunks, &mut rng)?;
        rep.row(vec![
            b.to_string(),
            format!("{:.0}", p.seq_tokens_per_sec()),
            format!("{:.0}", p.fused_tokens_per_sec()),
            format!("{:.2}x", p.speedup()),
            format!("{:.2e}", p.max_diff),
        ]);
        points.push(p);
    }
    println!("{}", rep.render());
    rep.save_csv(std::path::Path::new("results/stream_batched.csv"))?;

    // ---- tracing overhead: the same fused advance, spans off vs on ----
    // the disabled path is one relaxed atomic load per span site, so the
    // instrumentation must be ~free when off; the enabled run records
    // real spans into the per-thread rings
    let ob = *sessions.last().expect("at least one batch size");
    let off = fused_throughput_point(&model, &corpus, ob, fused_chunk, n_chunks, &mut rng)?;
    performer::obs::trace::set_enabled(true);
    let on = fused_throughput_point(&model, &corpus, ob, fused_chunk, n_chunks, &mut rng)?;
    performer::obs::trace::set_enabled(false);
    let traced_spans: usize =
        performer::obs::trace::drain().iter().map(|t| t.events.len() / 2).sum();
    let overhead_pct = (off.fused_tokens_per_sec() / on.fused_tokens_per_sec() - 1.0) * 100.0;
    println!(
        "trace overhead at B={ob}: disabled {:.0} tok/s, enabled {:.0} tok/s \
         ({overhead_pct:+.2}%, {traced_spans} spans recorded)",
        off.fused_tokens_per_sec(),
        on.fused_tokens_per_sec()
    );

    // ---- SIMD on/off: the same fused advance, dispatch vs scalar pin ----
    // records what the dense-core kernels buy the end-to-end stream path;
    // recorded, not asserted — the per-size gate lives in fig1_speed
    let level = active_level();
    set_level_override(Some(SimdLevel::Scalar));
    let scalar_run = fused_throughput_point(&model, &corpus, ob, fused_chunk, n_chunks, &mut rng)?;
    set_level_override(None);
    let simd_run = fused_throughput_point(&model, &corpus, ob, fused_chunk, n_chunks, &mut rng)?;
    let simd_speedup = simd_run.fused_tokens_per_sec() / scalar_run.fused_tokens_per_sec();
    println!(
        "simd dispatch at B={ob}: scalar {:.0} tok/s, {} {:.0} tok/s ({simd_speedup:.2}x)",
        scalar_run.fused_tokens_per_sec(),
        level.name(),
        simd_run.fused_tokens_per_sec()
    );

    // perf-trajectory artifact: tokens/sec sequential vs fused per B
    let json = obj(vec![
        ("bench", s("stream_batched")),
        ("mode", s(if smoke { "smoke" } else { "full" })),
        ("chunk", num(fused_chunk as f64)),
        ("chunks_per_session", num(n_chunks as f64)),
        ("threads", num(matmul_threads() as f64)),
        (
            "points",
            arr(points.iter().map(|p| {
                obj(vec![
                    ("sessions", num(p.n_sessions as f64)),
                    ("seq_tokens_per_sec", num(p.seq_tokens_per_sec())),
                    ("fused_tokens_per_sec", num(p.fused_tokens_per_sec())),
                    ("speedup", num(p.speedup())),
                    ("max_abs_diff", num(p.max_diff)),
                ])
            })),
        ),
        // recorded, not asserted: CI machines are too noisy for a hard
        // 2% gate, but the trajectory file keeps the number honest
        (
            "trace_overhead",
            obj(vec![
                ("sessions", num(ob as f64)),
                ("disabled_tokens_per_sec", num(off.fused_tokens_per_sec())),
                ("enabled_tokens_per_sec", num(on.fused_tokens_per_sec())),
                ("overhead_pct", num(overhead_pct)),
                ("spans_recorded", num(traced_spans as f64)),
            ]),
        ),
        // SIMD-on vs SIMD-off fused throughput at the largest batch size;
        // recorded, not asserted (see fig1_speed for the microbench gate)
        (
            "simd",
            obj(vec![
                ("level", s(level.name())),
                ("sessions", num(ob as f64)),
                ("scalar_tokens_per_sec", num(scalar_run.fused_tokens_per_sec())),
                ("simd_tokens_per_sec", num(simd_run.fused_tokens_per_sec())),
                ("speedup", num(simd_speedup)),
            ]),
        ),
    ]);
    std::fs::write("BENCH_stream_batched.json", json.to_string() + "\n")?;
    println!("wrote BENCH_stream_batched.json");

    // correctness is unconditional: fusing is an execution strategy,
    // not an approximation
    for p in &points {
        assert!(
            p.max_diff < 1e-4,
            "B={}: fused scores diverge from sequential by {}",
            p.n_sessions,
            p.max_diff
        );
    }
    let last = points.last().expect("at least one fused point");
    if smoke {
        println!(
            "smoke: B={} fused speedup {:.2}x (threshold enforced in full mode only)",
            last.n_sessions,
            last.speedup()
        );
    } else {
        assert!(
            last.speedup() >= 2.0,
            "B={} fused advance must clear 2x sequential aggregate throughput \
             (got {:.2}x)",
            last.n_sessions,
            last.speedup()
        );
        println!(
            "PASS: B={} fused advance at {:.2}x sequential aggregate throughput",
            last.n_sessions,
            last.speedup()
        );
    }
    Ok(())
}
