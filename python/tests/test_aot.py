"""AOT contract tests: the emitted metadata must exactly describe the
lowered HLO's parameters, and the init tensor file must cover every
param/feature slot. Runs against the real artifacts/ directory when
present (`make artifacts` first), otherwise emits a throwaway tiny
artifact into tmp_path."""

import json
import os
import struct

import pytest

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def _artifact(tag):
    meta_path = os.path.join(ARTIFACTS, f"{tag}.meta.json")
    if not os.path.exists(meta_path):
        pytest.skip(f"{tag} not built (run `make artifacts`)")
    with open(meta_path) as f:
        return json.load(f)


def _read_tensorfile(path):
    with open(path, "rb") as f:
        magic = f.read(8)
        assert magic == b"PFRMTENS"
        (hlen,) = struct.unpack("<I", f.read(4))
        header = json.loads(f.read(hlen))
        payload = f.read()
    return header, payload


def test_meta_parameter_count_matches_hlo():
    meta = _artifact("tiny_relu_bid_train")
    hlo_path = os.path.join(ARTIFACTS, "tiny_relu_bid_train.hlo.txt")
    with open(hlo_path) as f:
        hlo = f.read()
    # count ENTRY computation parameters in the HLO text
    entry = hlo[hlo.index("ENTRY"):]
    n_params = entry.count("parameter(")
    assert n_params == len(meta["inputs"]), (
        f"HLO has {n_params} parameters, meta declares {len(meta['inputs'])}"
    )


def test_train_meta_roles_balanced():
    meta = _artifact("tiny_relu_bid_train")
    roles = {}
    for i in meta["inputs"]:
        roles[i["role"]] = roles.get(i["role"], 0) + 1
    assert roles["param"] == roles["opt_m"] == roles["opt_v"]
    assert roles["opt_step"] == 1
    assert roles["tokens"] == roles["targets"] == roles["weights"] == 1
    out_roles = {}
    for o in meta["outputs"]:
        out_roles[o.get("role", "")] = out_roles.get(o.get("role", ""), 0) + 1
    assert out_roles["param"] == roles["param"]
    assert out_roles["loss"] == out_roles["acc"] == 1


def test_init_tensorfile_covers_all_slots():
    meta = _artifact("tiny_relu_bid_train")
    header, payload = _read_tensorfile(
        os.path.join(ARTIFACTS, "tiny_relu_bid_init.bin"))
    names = {h["name"] for h in header}
    for i in meta["inputs"]:
        if i["role"] in ("param", "feature"):
            key = f"{i['role']}:{i['name']}"
            assert key in names, f"init.bin missing {key}"
    # payload length must cover every declared tensor
    for h in header:
        n = 1
        for s in h["shape"]:
            n *= s
        assert h["offset"] + 4 * n <= len(payload)


def test_shapes_in_meta_match_init_sizes():
    meta = _artifact("tiny_relu_bid_train")
    header, _ = _read_tensorfile(os.path.join(ARTIFACTS, "tiny_relu_bid_init.bin"))
    by_name = {h["name"]: h for h in header}
    for i in meta["inputs"]:
        if i["role"] in ("param", "feature"):
            h = by_name[f"{i['role']}:{i['name']}"]
            assert h["shape"] == i["shape"], i["name"]


def test_fwd_meta_outputs_logits():
    meta = _artifact("tiny_relu_bid_fwd")
    (out,) = meta["outputs"]
    cfg = meta["config"]
    assert out["shape"] == [cfg["batch"], cfg["max_len"], cfg["vocab_size"]]


def test_index_lists_core_artifacts():
    path = os.path.join(ARTIFACTS, "index.json")
    if not os.path.exists(path):
        pytest.skip("index.json not built")
    with open(path) as f:
        index = json.load(f)
    names = {e["name"] for e in index}
    for required in ["tiny_relu_bid_train", "base_perf_relu_bid_train",
                     "base_exact_bid_train", "attn_favor_fwd_L1024"]:
        assert required in names
