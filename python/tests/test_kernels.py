"""Pallas kernels vs the pure-jnp oracle — the core L1 correctness signal.

hypothesis sweeps shapes (L, d, M) and feature kinds; every property
asserts allclose between the blocked Pallas implementation and the direct
transcription of the paper's equations in ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import favor, orf, ref

SETTINGS = dict(max_examples=12, deadline=None)


def rand(rng, *shape, scale=0.5):
    return jnp.asarray(rng.standard_normal(shape) * scale, jnp.float32)


@st.composite
def qkv_dims(draw):
    l = draw(st.sampled_from([16, 32, 48, 64, 128]))
    d = draw(st.sampled_from([4, 8, 16]))
    m = draw(st.sampled_from([8, 16, 32]))
    seed = draw(st.integers(0, 2**31 - 1))
    return l, d, m, seed


@given(qkv_dims())
@settings(**SETTINGS)
def test_feature_map_softmax_matches_ref(dims):
    l, d, m, seed = dims
    rng = np.random.default_rng(seed)
    x = rand(rng, l, d)
    w, b = orf.softmax_projection(m, d, seed=seed)
    w, b = jnp.asarray(w), jnp.asarray(b)
    got = favor.feature_map_pallas(x, w, b, f_name="cos", softmax_renorm=True, block_l=16)
    want = ref.softmax_feature_map(x, w, b)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@given(qkv_dims(), st.sampled_from(["relu", "sigmoid", "abs", "gelu", "tanh", "identity"]))
@settings(**SETTINGS)
def test_feature_map_generalized_matches_ref(dims, f_name):
    l, d, m, seed = dims
    rng = np.random.default_rng(seed)
    x = rand(rng, l, d)
    w, b = orf.generalized_projection(m, d, seed=seed)
    w, b = jnp.asarray(w), jnp.asarray(b)
    got = favor.feature_map_pallas(x, w, b, f_name=f_name, softmax_renorm=False,
                                   kernel_eps=1e-3, block_l=16)
    want = ref.generalized_feature_map(x, w, f_name, kernel_eps=1e-3, b=b)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@given(qkv_dims())
@settings(**SETTINGS)
def test_bidirectional_pallas_matches_oracle(dims):
    l, d, m, seed = dims
    rng = np.random.default_rng(seed)
    qp = jnp.abs(rand(rng, l, m)) + 1e-3  # nonneg features, like ReLU/softmax
    kp = jnp.abs(rand(rng, l, m)) + 1e-3
    v = rand(rng, l, d, scale=1.0)
    got = favor.favor_bidirectional_pallas(qp, kp, v, block_l=16)
    want = ref.favor_bidirectional(qp, kp, v)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@given(qkv_dims())
@settings(**SETTINGS)
def test_unidirectional_pallas_matches_oracle(dims):
    l, d, m, seed = dims
    rng = np.random.default_rng(seed)
    qp = jnp.abs(rand(rng, l, m)) + 1e-3
    kp = jnp.abs(rand(rng, l, m)) + 1e-3
    v = rand(rng, l, d, scale=1.0)
    got = favor.favor_unidirectional_pallas(qp, kp, v, block_l=16)
    want = ref.favor_unidirectional(qp, kp, v)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@given(qkv_dims())
@settings(**SETTINGS)
def test_unidirectional_scan_matches_oracle(dims):
    l, d, m, seed = dims
    rng = np.random.default_rng(seed)
    qp = jnp.abs(rand(rng, l, m)) + 1e-3
    kp = jnp.abs(rand(rng, l, m)) + 1e-3
    v = rand(rng, l, d, scale=1.0)
    got = ref.favor_unidirectional_scan(qp, kp, v, block=16)
    want = ref.favor_unidirectional_prefix(qp, kp, v)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@given(qkv_dims(), st.booleans())
@settings(**SETTINGS)
def test_exact_attention_pallas_matches_ref(dims, causal):
    l, d, _, seed = dims
    rng = np.random.default_rng(seed)
    q, k, v = rand(rng, l, d), rand(rng, l, d), rand(rng, l, d, scale=1.0)
    got = favor.exact_attention_pallas(q, k, v, causal=causal, block_l=16)
    want = (ref.exact_attention_unidirectional if causal
            else ref.exact_attention_bidirectional)(q, k, v)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_favor_softmax_approximates_exact_attention():
    """The headline estimator claim, at modest precision for small M."""
    rng = np.random.default_rng(0)
    l, d, m = 48, 8, 2048
    q, k, v = rand(rng, l, d, scale=0.4), rand(rng, l, d, scale=0.4), rand(rng, l, d, scale=1.0)
    w, b = orf.softmax_projection(m, d, mechanism="r-orf", seed=3)
    w, b = jnp.asarray(w), jnp.asarray(b)
    approx = favor.favor_attention_pallas(q, k, v, w, b, f_name="cos",
                                          softmax_renorm=True, block_l=16)
    exact = ref.exact_attention_bidirectional(q, k, v)
    err = float(jnp.mean(jnp.abs(approx - exact)))
    assert err < 0.05, f"approximation error {err}"


def test_unbiasedness_attention_matrix():
    """E[Q'(K')^T] = A: averaging independent feature draws converges."""
    rng = np.random.default_rng(1)
    l, d, m = 12, 8, 256
    q, k = rand(rng, l, d, scale=0.4), rand(rng, l, d, scale=0.4)
    a_exact = jnp.exp(q @ k.T / jnp.sqrt(jnp.float32(d)))
    acc = jnp.zeros((l, l))
    trials = 30
    for s in range(trials):
        w, b = orf.softmax_projection(m, d, mechanism="iid", seed=100 + s)
        qp = ref.softmax_feature_map(q, jnp.asarray(w), jnp.asarray(b))
        kp = ref.softmax_feature_map(k, jnp.asarray(w), jnp.asarray(b))
        acc = acc + qp @ kp.T
    est = acc / trials
    rel = float(jnp.max(jnp.abs(est - a_exact) / a_exact))
    assert rel < 0.15, f"max relative deviation {rel}"


def test_custom_vjp_gradients_match_ref():
    """Pallas fwd + ref bwd must equal pure-ref gradients."""
    rng = np.random.default_rng(2)
    l, d, m = 32, 8, 16
    q, k, v = rand(rng, l, d), rand(rng, l, d), rand(rng, l, d, scale=1.0)
    w, b = orf.generalized_projection(m, d, seed=5)
    w, b = jnp.asarray(w), jnp.asarray(b)

    attn = favor.make_favor_attention(f_name="relu", causal=False,
                                      softmax_renorm=False, kernel_eps=1e-3)

    def loss_pallas(q, k, v):
        return jnp.sum(attn(q, k, v, w, b) ** 2)

    def loss_ref(q, k, v):
        qp = ref.generalized_feature_map(q, w, "relu", kernel_eps=1e-3, b=b)
        kp = ref.generalized_feature_map(k, w, "relu", kernel_eps=1e-3, b=b)
        return jnp.sum(ref.favor_bidirectional_linear(qp, kp, v) ** 2)

    gp = jax.grad(loss_pallas, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(gp, gr):
        np.testing.assert_allclose(a, b_, rtol=1e-3, atol=1e-4)


def test_causality_pallas():
    """Future tokens must not influence past outputs (causal kernel)."""
    rng = np.random.default_rng(3)
    l, d, m = 32, 4, 8
    qp = jnp.abs(rand(rng, l, m)) + 1e-3
    kp = jnp.abs(rand(rng, l, m)) + 1e-3
    v = rand(rng, l, d)
    out1 = favor.favor_unidirectional_pallas(qp, kp, v, block_l=8)
    kp2 = kp.at[-1].set(9.0)
    v2 = v.at[-1].set(-9.0)
    out2 = favor.favor_unidirectional_pallas(qp, kp2, v2, block_l=8)
    np.testing.assert_allclose(out1[:-1], out2[:-1], rtol=1e-6, atol=1e-6)
    assert float(jnp.max(jnp.abs(out1[-1] - out2[-1]))) > 1e-4


@pytest.mark.parametrize("block_l", [8, 16, 32, 64])
def test_block_size_invariance(block_l):
    """The blocked kernels must be exact for any tiling."""
    rng = np.random.default_rng(4)
    l, d, m = 64, 8, 16
    qp = jnp.abs(rand(rng, l, m)) + 1e-3
    kp = jnp.abs(rand(rng, l, m)) + 1e-3
    v = rand(rng, l, d)
    want_b = ref.favor_bidirectional(qp, kp, v)
    want_u = ref.favor_unidirectional(qp, kp, v)
    got_b = favor.favor_bidirectional_pallas(qp, kp, v, block_l=block_l)
    got_u = favor.favor_unidirectional_pallas(qp, kp, v, block_l=block_l)
    np.testing.assert_allclose(got_b, want_b, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(got_u, want_u, rtol=2e-4, atol=2e-4)
