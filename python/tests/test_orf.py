"""Orthogonal random feature tests (Sec. 2.4): orthogonality, marginal
distributions, and the variance-reduction claim."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import orf


@pytest.mark.parametrize("mech", ["r-orf", "h-orf", "g-orf"])
def test_block_rows_orthogonal(mech):
    d = 16
    w = orf.projection_matrix(d, d, mechanism=mech, seed=0, chi_norms=False)
    gram = w @ w.T
    off = gram - np.diag(np.diag(gram))
    assert np.abs(off).max() < 1e-4, f"{mech} rows not orthogonal"


@pytest.mark.parametrize("mech", ["iid", "r-orf", "h-orf", "g-orf"])
def test_marginal_row_norms(mech):
    """chi-rescaled rows match Gaussian expected squared norm (= d)."""
    d = 16
    w = orf.projection_matrix(512, d, mechanism=mech, seed=1)
    sq = (w ** 2).sum(axis=1)
    assert abs(sq.mean() - d) < 2.0, f"{mech}: E||w||^2 = {sq.mean()}"


@given(st.integers(1, 64), st.sampled_from([4, 8, 16]))
@settings(max_examples=10, deadline=None)
def test_projection_shape(m, d):
    w = orf.projection_matrix(m, d, mechanism="r-orf", seed=2)
    assert w.shape == (m, d)
    assert w.dtype == np.float32


def test_softmax_projection_scale():
    """Softmax features use sigma = d^{-1/4} (Gaussian kernel bandwidth)."""
    d = 16
    w, b = orf.softmax_projection(2048, d, mechanism="iid", seed=3)
    var = w.var()
    expect = 1.0 / np.sqrt(d)  # sigma^2 = 1/sqrt(d)
    assert abs(var - expect) / expect < 0.1
    assert (b >= 0).all() and (b <= 2 * np.pi).all()


def test_orf_variance_reduction():
    """Sec. 3: ORF softmax-kernel estimates beat iid at the same M."""
    d, m = 8, 8
    rng = np.random.default_rng(0)
    q = rng.standard_normal(d).astype(np.float32) * 0.5
    k = rng.standard_normal(d).astype(np.float32) * 0.5
    r = 2.0 * np.sqrt(d)
    exact = np.exp(q @ k / np.sqrt(d))

    def estimate(mech, seed):
        w, b = orf.softmax_projection(m, d, mechanism=mech, seed=seed)
        dq = np.exp((q @ q) / r)
        dk = np.exp((k @ k) / r)
        pq = dq * np.sqrt(2.0 / m) * np.cos(w @ q + b)
        pk = dk * np.sqrt(2.0 / m) * np.cos(w @ k + b)
        return pq @ pk

    errs = {mech: np.array([estimate(mech, s) - exact for s in range(400)])
            for mech in ("iid", "r-orf")}
    assert (errs["r-orf"] ** 2).mean() < (errs["iid"] ** 2).mean()


def test_hadamard_requires_power_of_two():
    with pytest.raises(AssertionError):
        orf.projection_matrix(8, 12, mechanism="h-orf", seed=0)


def test_determinism():
    a = orf.projection_matrix(32, 8, mechanism="r-orf", seed=7)
    b = orf.projection_matrix(32, 8, mechanism="r-orf", seed=7)
    np.testing.assert_array_equal(a, b)
    c = orf.projection_matrix(32, 8, mechanism="r-orf", seed=8)
    assert np.abs(a - c).max() > 1e-3
