"""L2 model tests: shapes, training dynamics, attention-variant parity,
masking semantics and the Adam step."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


def tiny(attention="favor-relu", uni=False, use_pallas=False, **kw):
    return M.ModelConfig(d_model=32, n_heads=2, n_layers=2, d_ff=64, max_len=32,
                         n_features=16, attention=attention, unidirectional=uni,
                         use_pallas=use_pallas, lsh_chunk=8, **kw)


def data(cfg, b=2, seed=0):
    rng = np.random.default_rng(seed)
    toks = jnp.asarray(rng.integers(4, 29, (b, cfg.max_len)), jnp.int32)
    wts = jnp.ones((b, cfg.max_len), jnp.float32)
    return toks, toks, wts


@pytest.mark.parametrize("attention", ["exact", "favor-relu", "favor-softmax",
                                       "lsh", "identity"])
@pytest.mark.parametrize("uni", [False, True])
def test_forward_shapes_finite(attention, uni):
    cfg = tiny(attention, uni)
    p = M.init_params(cfg)
    f = M.init_features(cfg)
    toks, _, _ = data(cfg)
    logits = M.forward(cfg, p, f, toks)
    assert logits.shape == (2, cfg.max_len, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("attention", ["exact", "favor-relu", "lsh"])
def test_loss_decreases_over_steps(attention):
    cfg = tiny(attention)
    p = M.init_params(cfg)
    f = M.init_features(cfg)
    opt = M.init_opt_state(p)
    toks, tg, wts = data(cfg)
    step = jax.jit(lambda p_, o_, f_: M.train_step(cfg, p_, o_, f_, toks, tg, wts))
    losses = []
    for _ in range(8):
        p, opt, loss, _ = step(p, opt, f)
        losses.append(float(loss))
    assert losses[-1] < losses[0], f"no learning: {losses}"


def test_pallas_and_jnp_paths_agree():
    """use_pallas toggles the implementation, not the math."""
    for uni in (False, True):
        cfg_a = tiny("favor-relu", uni, use_pallas=True)
        cfg_b = tiny("favor-relu", uni, use_pallas=False)
        p = M.init_params(cfg_a)
        f = M.init_features(cfg_a)
        toks, _, _ = data(cfg_a)
        la = M.forward(cfg_a, p, f, toks)
        lb = M.forward(cfg_b, p, f, toks)
        np.testing.assert_allclose(la, lb, rtol=2e-3, atol=2e-3)


def test_unidirectional_model_is_causal():
    cfg = tiny("favor-relu", uni=True)
    p = M.init_params(cfg)
    f = M.init_features(cfg)
    toks, _, _ = data(cfg)
    logits = M.forward(cfg, p, f, toks)
    toks2 = toks.at[:, -1].set(5)
    logits2 = M.forward(cfg, p, f, toks2)
    np.testing.assert_allclose(logits[:, :-1], logits2[:, :-1], rtol=1e-5, atol=1e-5)


def test_bidirectional_model_is_not_causal():
    cfg = tiny("favor-relu", uni=False)
    p = M.init_params(cfg)
    f = M.init_features(cfg)
    toks, _, _ = data(cfg)
    logits = M.forward(cfg, p, f, toks)
    toks2 = toks.at[:, -1].set(5)
    logits2 = M.forward(cfg, p, f, toks2)
    assert float(jnp.max(jnp.abs(logits[:, 0] - logits2[:, 0]))) > 1e-6


def test_weighted_loss_ignores_unweighted_positions():
    cfg = tiny("exact")
    p = M.init_params(cfg)
    f = M.init_features(cfg)
    toks, tg, _ = data(cfg)
    wts = jnp.zeros((2, cfg.max_len), jnp.float32).at[:, :4].set(1.0)
    loss1, _ = M.loss_fn(cfg, p, f, toks, tg, wts)
    tg2 = tg.at[:, 10:].set(7)  # change only unweighted targets
    loss2, _ = M.loss_fn(cfg, p, f, toks, tg2, wts)
    assert float(jnp.abs(loss1 - loss2)) < 1e-7


def test_adam_bias_correction_first_step():
    """After one step with constant grad g, update ≈ -lr * sign-ish."""
    cfg = tiny("identity")
    p = {"x": jnp.ones(4)}
    opt = M.init_opt_state(p)
    # emulate train_step's update math directly on a toy tree

    step = opt["step"] + 1.0
    g = jnp.full(4, 0.01)
    b1, b2 = M.ADAM["b1"], M.ADAM["b2"]
    m = (1 - b1) * g
    v = (1 - b2) * g * g
    mhat = m / (1 - b1 ** step)
    vhat = v / (1 - b2 ** step)
    upd = mhat / (jnp.sqrt(vhat) + M.ADAM["eps"])
    np.testing.assert_allclose(upd, jnp.ones(4), rtol=1e-4)


def test_grad_clip_bounds_update_norm():
    cfg = tiny("exact")
    p = M.init_params(cfg)
    f = M.init_features(cfg)
    opt = M.init_opt_state(p)
    toks, tg, wts = data(cfg)
    # scale loss by a huge factor via weights to force large grads
    p2, _, loss, _ = M.train_step(cfg, p, opt, f, toks, tg, wts * 1e6)
    assert bool(jnp.isfinite(loss))
    for a, b in zip(jax.tree_util.tree_leaves(p), jax.tree_util.tree_leaves(p2)):
        assert bool(jnp.all(jnp.isfinite(b)))
        # Adam step bounded by ~lr * (1 + wd)
        assert float(jnp.max(jnp.abs(a - b))) < 0.1


def test_ga_kernel_sweep_forward_finite():
    for f_name in ["sigmoid", "exp", "relu", "abs", "gelu", "cos", "tanh", "identity"]:
        cfg = tiny(f"favor-{f_name}")
        p = M.init_params(cfg)
        f = M.init_features(cfg)
        toks, _, _ = data(cfg)
        logits = M.forward(cfg, p, f, toks)
        assert bool(jnp.all(jnp.isfinite(logits))), f_name


def test_param_count_matches_formula():
    cfg = tiny()
    p = M.init_params(cfg)
    d, ff, v = cfg.d_model, cfg.d_ff, cfg.vocab_size
    per_layer = 2 * 2 * d + (d * 3 * d + 3 * d) + (d * d + d) + (d * ff + ff) + (ff * d + d)
    expect = v * d + 2 * d + cfg.n_layers * per_layer
    assert M.count_params(p) == expect


def test_lsh_respects_chunk_divisibility():
    cfg = tiny("lsh")
    assert cfg.max_len % cfg.lsh_chunk == 0
    p = M.init_params(cfg)
    f = M.init_features(cfg)
    toks, _, _ = data(cfg)
    logits = M.forward(cfg, p, f, toks)
    assert bool(jnp.all(jnp.isfinite(logits)))
