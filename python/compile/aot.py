"""AOT compiler: lower every (model, task) the rust runtime needs to HLO
*text* + a .meta.json I/O contract, under artifacts/.

HLO text (NOT HloModuleProto.serialize()) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version the `xla` 0.1.6 crate binds) rejects; the text parser
reassigns ids and round-trips cleanly.

Artifact kinds:
  fwd        (params.., features.., tokens)                   -> (logits,)
  train_step (params.., m.., v.., step, features.., tokens,
              targets, weights) -> (params'.., m'.., v'.., step', loss, acc)
  eval_step  (params.., features.., tokens, targets, weights) -> (loss, acc)
  attn_op    (q, k, v[, w, b])                                -> (out,)
             and _bwd variants returning input gradients, for the Fig. 1/
             14/15 timing benches.

Run `python -m compile.aot` from python/ (the Makefile does). Emits
artifacts/index.json describing everything written.
"""

import argparse
import dataclasses
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model as M
from compile.kernels import favor as favor_k
from compile.kernels import orf


# ---------------------------------------------------------------------------
# Lowering helpers
# ---------------------------------------------------------------------------

def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants is load-bearing: the default printer elides
    # big constants (positional encodings, tril masks) as "{...}", which
    # xla_extension 0.5.1's text parser silently reads back as ZEROS.
    return comp.as_hlo_text(print_large_constants=True)


def _paths(tree):
    """Stable flattened (path-string, leaf) pairs for a params pytree."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path
        )
        out.append((name, leaf))
    return out


def _dtype_name(x):
    return {"float32": "f32", "int32": "i32"}[str(x.dtype)]


def _spec(x):
    return jax.ShapeDtypeStruct(x.shape, x.dtype)


def _input_entry(name, role, leaf):
    return {"name": name, "role": role, "shape": list(leaf.shape),
            "dtype": _dtype_name(leaf)}


# ---------------------------------------------------------------------------
# Named model configurations (scaled-down per DESIGN.md §Substitutions)
# ---------------------------------------------------------------------------

def cfg(**kw):
    return M.ModelConfig(**kw)


# batch size baked into each artifact (PJRT executables are shape-static)
CONFIGS = {
    # testing / quickstart
    "tiny": (cfg(d_model=64, n_heads=2, n_layers=2, d_ff=128, max_len=64,
                 n_features=32), 4),
    # the repo's workhorse protein-MLM model
    "base": (cfg(d_model=128, n_heads=4, n_layers=3, d_ff=512, max_len=128,
                 n_features=64), 8),
    # long-context concatenated-protein model (paper L=8192, scaled)
    "long": (cfg(d_model=128, n_heads=4, n_layers=2, d_ff=512, max_len=1024,
                 n_features=64), 1),
}


def variant(base_cfg: M.ModelConfig, attention: str, unidirectional: bool,
            use_pallas=None) -> M.ModelConfig:
    if use_pallas is None:
        # Pallas on the FAVOR/exact hot paths; jnp for the rest
        use_pallas = attention.startswith("favor") or attention == "exact"
    return dataclasses.replace(base_cfg, attention=attention,
                               unidirectional=unidirectional,
                               use_pallas=use_pallas)


# ---------------------------------------------------------------------------
# Artifact emission
# ---------------------------------------------------------------------------

class Emitter:
    def __init__(self, out_dir, force=False, only=None):
        self.out_dir = out_dir
        self.force = force
        self.only = only
        self.index = []
        os.makedirs(out_dir, exist_ok=True)

    def _skip(self, name):
        if self.only and self.only not in name:
            return True
        path = os.path.join(self.out_dir, f"{name}.hlo.txt")
        return (not self.force) and os.path.exists(path)

    def _write(self, name, hlo, meta):
        with open(os.path.join(self.out_dir, f"{name}.hlo.txt"), "w") as f:
            f.write(hlo)
        with open(os.path.join(self.out_dir, f"{name}.meta.json"), "w") as f:
            json.dump(meta, f, indent=1)
        print(f"  wrote {name}: {len(hlo)/1e6:.2f} MB hlo, "
              f"{len(meta['inputs'])} inputs")

    def _record(self, name, meta):
        self.index.append({"name": name, "kind": meta["kind"],
                           "config": meta.get("config")})

    def model_artifacts(self, tag, mcfg: M.ModelConfig, batch, kinds):
        # Pallas (interpret-mode) lowers to grid loops that xla_extension
        # 0.5.1's CPU backend executes ~500x slower than the fused-jnp
        # formulation of the same math (see EXPERIMENTS.md §Perf). The
        # serving fwd keeps the Pallas kernels (the L1 composition proof);
        # train/eval use the identical-math jnp path for throughput.
        mcfg_train = dataclasses.replace(mcfg, use_pallas=False)
        params = M.init_params(mcfg, seed=0)
        feats = M.init_features(mcfg, seed=0)
        p_flat = _paths(params)
        f_flat = _paths(feats)
        l = mcfg.max_len
        tok_spec = jax.ShapeDtypeStruct((batch, l), jnp.int32)
        f32_bl = jax.ShapeDtypeStruct((batch, l), jnp.float32)

        cfg_meta = {**dataclasses.asdict(mcfg), "batch": batch,
                    "param_count": M.count_params(params)}

        def p_specs():
            return [ _spec(x) for _, x in p_flat ]

        def f_specs():
            return [ _spec(x) for _, x in f_flat ]

        treedef_p = jax.tree_util.tree_structure(params)
        treedef_f = jax.tree_util.tree_structure(feats)

        def unflat_p(xs):
            return jax.tree_util.tree_unflatten(treedef_p, list(xs))

        def unflat_f(xs):
            return jax.tree_util.tree_unflatten(treedef_f, list(xs))

        if "fwd" in kinds:
            name = f"{tag}_fwd"
            if not self._skip(name):
                n_p, n_f = len(p_flat), len(f_flat)

                def fwd_fn(*args):
                    p = unflat_p(args[:n_p])
                    f = unflat_f(args[n_p:n_p + n_f])
                    tokens = args[n_p + n_f]
                    return (M.forward(mcfg, p, f, tokens),)

                lowered = jax.jit(fwd_fn).lower(*p_specs(), *f_specs(), tok_spec)
                meta = {
                    "kind": "fwd", "config": cfg_meta,
                    "inputs": [_input_entry(n, "param", x) for n, x in p_flat]
                    + [_input_entry(n, "feature", x) for n, x in f_flat]
                    + [{"name": "tokens", "role": "tokens",
                        "shape": [batch, l], "dtype": "i32"}],
                    "outputs": [{"name": "logits",
                                 "shape": [batch, l, mcfg.vocab_size],
                                 "dtype": "f32"}],
                }
                self._write(name, to_hlo_text(lowered), meta)
            self._record(name, {"kind": "fwd", "config": cfg_meta})

        if "train" in kinds:
            name = f"{tag}_train"
            if not self._skip(name):
                n_p, n_f = len(p_flat), len(f_flat)
                step_spec = jax.ShapeDtypeStruct((), jnp.float32)

                def train_fn(*args):
                    i = 0
                    p = unflat_p(args[i:i + n_p]); i += n_p
                    m = unflat_p(args[i:i + n_p]); i += n_p
                    v = unflat_p(args[i:i + n_p]); i += n_p
                    step = args[i]; i += 1
                    f = unflat_f(args[i:i + n_f]); i += n_f
                    tokens, targets, weights = args[i], args[i + 1], args[i + 2]
                    opt = {"m": m, "v": v, "step": step}
                    p2, opt2, loss, acc = M.train_step(
                        mcfg_train, p, opt, f, tokens, targets, weights)
                    return (*jax.tree_util.tree_leaves(p2),
                            *jax.tree_util.tree_leaves(opt2["m"]),
                            *jax.tree_util.tree_leaves(opt2["v"]),
                            opt2["step"], loss, acc)

                lowered = jax.jit(train_fn).lower(
                    *p_specs(), *p_specs(), *p_specs(), step_spec,
                    *f_specs(), tok_spec,
                    jax.ShapeDtypeStruct((batch, l), jnp.int32), f32_bl)
                meta = {
                    "kind": "train_step", "config": cfg_meta,
                    "inputs":
                        [_input_entry(n, "param", x) for n, x in p_flat]
                        + [_input_entry(n, "opt_m", x) for n, x in p_flat]
                        + [_input_entry(n, "opt_v", x) for n, x in p_flat]
                        + [{"name": "step", "role": "opt_step", "shape": [],
                            "dtype": "f32"}]
                        + [_input_entry(n, "feature", x) for n, x in f_flat]
                        + [{"name": "tokens", "role": "tokens",
                            "shape": [batch, l], "dtype": "i32"},
                           {"name": "targets", "role": "targets",
                            "shape": [batch, l], "dtype": "i32"},
                           {"name": "weights", "role": "weights",
                            "shape": [batch, l], "dtype": "f32"}],
                    "outputs":
                        [{"name": n, "role": "param", "shape": list(x.shape),
                          "dtype": "f32"} for n, x in p_flat]
                        + [{"name": n, "role": "opt_m", "shape": list(x.shape),
                            "dtype": "f32"} for n, x in p_flat]
                        + [{"name": n, "role": "opt_v", "shape": list(x.shape),
                            "dtype": "f32"} for n, x in p_flat]
                        + [{"name": "step", "role": "opt_step", "shape": [],
                            "dtype": "f32"},
                           {"name": "loss", "role": "loss", "shape": [],
                            "dtype": "f32"},
                           {"name": "acc", "role": "acc", "shape": [],
                            "dtype": "f32"}],
                }
                self._write(name, to_hlo_text(lowered), meta)
            self._record(name, {"kind": "train_step", "config": cfg_meta})

        if "eval" in kinds:
            name = f"{tag}_eval"
            if not self._skip(name):
                n_p, n_f = len(p_flat), len(f_flat)

                def eval_fn(*args):
                    p = unflat_p(args[:n_p])
                    f = unflat_f(args[n_p:n_p + n_f])
                    tokens, targets, weights = args[n_p + n_f:]
                    loss, acc = M.eval_step(mcfg_train, p, f, tokens, targets, weights)
                    return (loss, acc)

                lowered = jax.jit(eval_fn).lower(
                    *p_specs(), *f_specs(), tok_spec,
                    jax.ShapeDtypeStruct((batch, l), jnp.int32), f32_bl)
                meta = {
                    "kind": "eval_step", "config": cfg_meta,
                    "inputs": [_input_entry(n, "param", x) for n, x in p_flat]
                    + [_input_entry(n, "feature", x) for n, x in f_flat]
                    + [{"name": "tokens", "role": "tokens",
                        "shape": [batch, l], "dtype": "i32"},
                       {"name": "targets", "role": "targets",
                        "shape": [batch, l], "dtype": "i32"},
                       {"name": "weights", "role": "weights",
                        "shape": [batch, l], "dtype": "f32"}],
                    "outputs": [
                        {"name": "loss", "shape": [], "dtype": "f32"},
                        {"name": "acc", "shape": [], "dtype": "f32"}],
                }
                self._write(name, to_hlo_text(lowered), meta)
            self._record(name, {"kind": "eval_step", "config": cfg_meta})

        # initial values for rust to bootstrap training (params + features):
        # simple framed format (see rust/src/runtime/tensorfile.rs) —
        # magic, u32 json header length, json manifest, raw LE f32 payload.
        init_name = f"{tag}_init"
        init_path = os.path.join(self.out_dir, f"{init_name}.bin")
        if self.force or not os.path.exists(init_path):
            arrs = [(f"param:{n}", np.asarray(x)) for n, x in p_flat]
            arrs += [(f"feature:{n}", np.asarray(x)) for n, x in f_flat]
            header, offset = [], 0
            for n, x in arrs:
                header.append({"name": n, "shape": list(x.shape),
                               "dtype": "f32", "offset": offset})
                offset += x.size * 4
            hjson = json.dumps(header).encode()
            with open(init_path, "wb") as f:
                f.write(b"PFRMTENS")
                f.write(np.uint32(len(hjson)).tobytes())
                f.write(hjson)
                for _, x in arrs:
                    f.write(np.ascontiguousarray(x, np.float32).tobytes())
        self._record(init_name, {"kind": "init", "config": cfg_meta})

    def attention_op(self, name, l, dh, m_feats, mech, causal, bwd, bh=4):
        """Attention-op-only artifacts for the timing figures."""
        if self._skip(name):
            self._record(name, {"kind": "attn_op", "config": {"l": l}})
            return
        q = jax.ShapeDtypeStruct((bh, l, dh), jnp.float32)
        inputs = [{"name": t, "role": "input", "shape": [bh, l, dh],
                   "dtype": "f32"} for t in ("q", "k", "v")]

        if mech == "exact":
            from compile.kernels import ref as ref_k

            def op(q, k, v):
                f = (ref_k.exact_attention_unidirectional if causal
                     else ref_k.exact_attention_bidirectional)
                return jax.vmap(f)(q, k, v)
            args = (q, q, q)
        elif mech == "favor_pallas":
            # interpret-mode Pallas variant, kept to quantify the
            # old-XLA interpret overhead (EXPERIMENTS.md §Perf)
            w_np, b_np = orf.generalized_projection(m_feats, dh, seed=0)
            w = jax.ShapeDtypeStruct(w_np.shape, jnp.float32)
            b = jax.ShapeDtypeStruct(b_np.shape, jnp.float32)
            inputs += [
                {"name": "w", "role": "feature", "shape": list(w_np.shape),
                 "dtype": "f32"},
                {"name": "b", "role": "feature", "shape": list(b_np.shape),
                 "dtype": "f32"}]

            def op(q, k, v, w, b):
                f = favor_k.make_favor_attention(
                    f_name="relu", causal=causal, softmax_renorm=False,
                    kernel_eps=1e-3)
                return jax.vmap(lambda q_, k_, v_: f(q_, k_, v_, w, b))(q, k, v)
            args = (q, q, q, w, b)
        elif mech == "favor":
            w_np, b_np = orf.generalized_projection(m_feats, dh, seed=0)
            w = jax.ShapeDtypeStruct(w_np.shape, jnp.float32)
            b = jax.ShapeDtypeStruct(b_np.shape, jnp.float32)
            inputs += [
                {"name": "w", "role": "feature", "shape": list(w_np.shape),
                 "dtype": "f32"},
                {"name": "b", "role": "feature", "shape": list(b_np.shape),
                 "dtype": "f32"}]

            from compile.kernels import ref as ref_k

            def op(q, k, v, w, b):
                def head(q_, k_, v_):
                    qp = ref_k.generalized_feature_map(q_, w, "relu", kernel_eps=1e-3, b=b)
                    kp = ref_k.generalized_feature_map(k_, w, "relu", kernel_eps=1e-3, b=b)
                    if causal:
                        return ref_k.favor_unidirectional_scan(qp, kp, v_)
                    return ref_k.favor_bidirectional_linear(qp, kp, v_)
                return jax.vmap(head)(q, k, v)
            args = (q, q, q, w, b)
        else:  # identity — "X (OPT)" in Fig. 1
            def op(q, k, v):
                # keep q, k alive in the graph (jit would prune unused
                # args and break the I/O contract)
                return v + 0.0 * q + 0.0 * k
            args = (q, q, q)

        if bwd:
            def full(*a):
                def scalar(*inner):
                    out = op(*inner)
                    return jnp.sum(out * out)
                g = jax.grad(scalar, argnums=(0, 1, 2))(*a)
                return g
            outputs = [{"name": f"d{t}", "shape": [bh, l, dh], "dtype": "f32"}
                       for t in ("q", "k", "v")]
        else:
            def full(*a):
                return (op(*a),)
            outputs = [{"name": "out", "shape": [bh, l, dh], "dtype": "f32"}]

        lowered = jax.jit(full).lower(*args)
        meta = {"kind": "attn_op",
                "config": {"l": l, "d_head": dh, "m": m_feats, "mech": mech,
                           "causal": causal, "bwd": bwd, "bh": bh},
                "inputs": inputs, "outputs": outputs}
        self._write(name, to_hlo_text(lowered), meta)
        self._record(name, meta)

    def finish(self):
        with open(os.path.join(self.out_dir, "index.json"), "w") as f:
            json.dump(self.index, f, indent=1)
        print(f"index: {len(self.index)} artifacts")


# ---------------------------------------------------------------------------
# The manifest: everything the rust side loads
# ---------------------------------------------------------------------------

def emit_all(em: Emitter):
    # quickstart + unit-test model
    tiny, tb = CONFIGS["tiny"]
    em.model_artifacts("tiny_relu_bid", variant(tiny, "favor-relu", False),
                       tb, ("fwd", "train", "eval"))

    # Fig. 4 (+Table 2): UNI and BID sweeps on the base model
    base, bb = CONFIGS["base"]
    for uni, utag in ((False, "bid"), (True, "uni")):
        for attn in ("exact", "favor-relu", "favor-softmax", "lsh"):
            atag = attn.replace("favor-", "perf_")
            em.model_artifacts(f"base_{atag}_{utag}", variant(base, attn, uni),
                               bb, ("fwd", "train", "eval"))

    # Fig. 5: long-context concatenated proteins — Performer (full size)
    # vs smaller exact Transformers (layer sweep), scaled from L=8192
    long_cfg, lb = CONFIGS["long"]
    em.model_artifacts("long_perf_relu_uni", variant(long_cfg, "favor-relu", True),
                       lb, ("train", "eval"))
    for n_layers in (1, 2):
        small = dataclasses.replace(long_cfg, n_layers=n_layers, d_model=64,
                                    n_heads=4, d_ff=256)
        em.model_artifacts(f"long_exact_l{n_layers}_uni",
                           variant(small, "exact", True), lb, ("train", "eval"))

    # Fig. 12/13: generalized-attention kernel sweep (BID, short model)
    sweep = dataclasses.replace(tiny, max_len=64)
    for f_name in ("sigmoid", "exp", "relu", "abs", "gelu", "cos", "tanh",
                   "identity"):
        em.model_artifacts(f"ga_{f_name}_bid",
                           variant(sweep, f"favor-{f_name}", False), tb,
                           ("train", "eval"))

    # Pallas-interpret overhead quantification (EXPERIMENTS.md §Perf)
    for l in (256, 1024):
        em.attention_op(f"attn_favor_pallas_fwd_L{l}", l, 64, 128,
                        "favor_pallas", causal=False, bwd=False, bh=4)

    # Fig. 1 / 14 / 15: attention-op timing artifacts
    for l in (128, 256, 512, 1024, 2048, 4096):
        for mech in ("exact", "favor", "identity"):
            if mech == "exact" and l > 2048:
                continue  # the point of the figure: exact stops scaling
            for bwd in (False, True):
                btag = "bwd" if bwd else "fwd"
                em.attention_op(f"attn_{mech}_{btag}_L{l}", l, 64, 128,
                                mech, causal=False, bwd=bwd, bh=4)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--only", default=None,
                    help="substring filter on artifact names")
    args = ap.parse_args()
    em = Emitter(args.out, force=args.force, only=args.only)
    emit_all(em)
    em.finish()


if __name__ == "__main__":
    main()
