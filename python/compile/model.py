"""L2: the Performer / Transformer protein language model in JAX.

A pre-LN Transformer whose attention is pluggable:

  * "exact"          — Eq. (1)/(2) softmax attention (the baseline).
  * "favor-softmax"  — FAVOR approximating softmax attention (Eq. 10/13).
  * "favor-relu"     — Generalized Attention, f = ReLU (the paper's best
                       protein configuration, Appendix B.3).
  * "favor-<f>"      — other GA kernels (sigmoid/exp/abs/gelu/cos/tanh/
                       identity) for the Fig. 12/13 kernel sweep.
  * "lsh"            — simplified Reformer-style LSH attention baseline.
  * "identity"       — attention returns V ("X (OPT)" line in Fig. 1).

Both directions: bidirectional (masked LM, BERT-style) and unidirectional
(causal next-token LM). train_step carries in-graph Adam with the paper's
hyperparameters (Appendix B.1: lr 1e-3, beta1 .9, beta2 .98, eps 1e-9,
grad clip 0.5, weight decay 0.1).

Everything is pure functions over a params dict so the whole train step
AOT-lowers to a single HLO module executed from rust.
"""

import dataclasses
import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from compile.kernels import favor as favor_k
from compile.kernels import orf
from compile.kernels import ref as ref_k


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    vocab_size: int = 30          # 20 std + 5 anomalous AAs + specials
    d_model: int = 128
    n_heads: int = 4
    n_layers: int = 2
    d_ff: int = 512
    max_len: int = 128
    attention: str = "favor-relu"
    unidirectional: bool = False
    n_features: int = 64          # M, the paper's default is 256 at d=512
    orf_mechanism: str = "r-orf"  # iid | r-orf | h-orf | g-orf
    use_pallas: bool = True       # False -> fused-jnp (same math) for speed
    lsh_chunk: int = 32
    dropout: float = 0.0          # paper trains with 0.1; eval/AOT path is 0

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, seed: int = 0) -> Dict[str, Any]:
    """Nested dict of f32 arrays. 'features' holds the FAVOR projection
    (W, b) — non-trainable, excluded from Adam, resampled from rust when
    the paper's feature-resampling strategy is on."""
    rng = np.random.default_rng(seed)
    d, h, dh, ff = cfg.d_model, cfg.n_heads, cfg.d_head, cfg.d_ff

    def dense(n_in, n_out):
        return {
            "w": (rng.standard_normal((n_in, n_out)) / np.sqrt(n_in)).astype(np.float32),
            "b": np.zeros(n_out, dtype=np.float32),
        }

    layers = []
    for _ in range(cfg.n_layers):
        layers.append({
            "ln1": {"g": np.ones(d, np.float32), "b": np.zeros(d, np.float32)},
            "qkv": dense(d, 3 * d),
            "proj": dense(d, d),
            "ln2": {"g": np.ones(d, np.float32), "b": np.zeros(d, np.float32)},
            "ff1": dense(d, ff),
            "ff2": dense(ff, d),
        })
    params = {
        "embed": (rng.standard_normal((cfg.vocab_size, d)) * 0.02).astype(np.float32),
        "lnf": {"g": np.ones(d, np.float32), "b": np.zeros(d, np.float32)},
        "layers": layers,
    }
    return jax.tree_util.tree_map(jnp.asarray, params)


def init_features(cfg: ModelConfig, seed: int = 0) -> Dict[str, Any]:
    """FAVOR feature state: W (M, d_head) and b (M,) per the mechanism; for
    LSH, the random rotation used for bucketing."""
    if cfg.attention.startswith("favor-"):
        f_name = cfg.attention.split("-", 1)[1]
        if f_name == "softmax":
            w, b = orf.softmax_projection(cfg.n_features, cfg.d_head,
                                          mechanism=cfg.orf_mechanism, seed=seed)
        else:
            w, b = orf.generalized_projection(cfg.n_features, cfg.d_head,
                                              mechanism=cfg.orf_mechanism, seed=seed)
        return {"w": jnp.asarray(w), "b": jnp.asarray(b)}
    if cfg.attention == "lsh":
        rng = np.random.default_rng(seed + 7)
        n_buckets = max(2, cfg.max_len // cfg.lsh_chunk)
        rot = rng.standard_normal((cfg.d_head, n_buckets // 2 + 1)).astype(np.float32)
        return {"rot": jnp.asarray(rot)}
    # exact/identity have no feature state — an unused placeholder input
    # would be pruned by jax at lowering and break the I/O contract
    return {}


def count_params(params) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(params))


# ---------------------------------------------------------------------------
# Attention mechanisms (per batch-and-head 2D inputs via vmap)
# ---------------------------------------------------------------------------

def _favor_head(q, k, v, w, b, *, f_name, causal, use_pallas):
    if f_name == "softmax":
        renorm, eps = True, 1e-6
        fm = "cos"
    else:
        renorm, eps = False, 1e-3
        fm = f_name
    if use_pallas:
        attn = favor_k.make_favor_attention(
            f_name=fm, causal=causal, softmax_renorm=renorm, kernel_eps=eps)
        return attn(q, k, v, w, b)
    if renorm:
        qp = ref_k.softmax_feature_map(q, w, b)
        kp = ref_k.softmax_feature_map(k, w, b)
    else:
        qp = ref_k.generalized_feature_map(q, w, fm, kernel_eps=eps, b=b)
        kp = ref_k.generalized_feature_map(k, w, fm, kernel_eps=eps, b=b)
    if causal:
        return ref_k.favor_unidirectional_scan(qp, kp, v)
    return ref_k.favor_bidirectional_linear(qp, kp, v)


def _exact_head(q, k, v, *, causal, use_pallas):
    if use_pallas:
        return favor_k.make_exact_attention(causal=causal)(q, k, v)
    if causal:
        return ref_k.exact_attention_unidirectional(q, k, v)
    return ref_k.exact_attention_bidirectional(q, k, v)


def _lsh_head(q, k, v, rot, *, causal, chunk):
    """Simplified Reformer [29]: shared-QK LSH bucketing via random
    rotations, sort by bucket, attend within chunk + previous chunk.
    This is the paper's sparse-attention comparator (Fig. 4)."""
    l, dh = q.shape
    qk = q / (jnp.linalg.norm(q, axis=-1, keepdims=True) + 1e-6)  # shared QK
    proj = qk @ rot                                               # (L, nb/2)
    buckets = jnp.argmax(jnp.concatenate([proj, -proj], -1), -1)  # (L,)
    order = jnp.argsort(buckets * l + jnp.arange(l))              # stable
    undo = jnp.argsort(order)
    qs, vs, pos = qk[order], v[order], order

    n_chunks = l // chunk
    qs = qs.reshape(n_chunks, chunk, dh)
    vs = vs.reshape(n_chunks, chunk, dh)
    pos = pos.reshape(n_chunks, chunk)
    # keys = own chunk + previous chunk (Reformer's lookback)
    ks_prev = jnp.roll(qs, 1, axis=0)
    vs_prev = jnp.roll(vs, 1, axis=0)
    pos_prev = jnp.roll(pos, 1, axis=0)
    ks2 = jnp.concatenate([qs, ks_prev], axis=1)                  # (nc, 2c, dh)
    vs2 = jnp.concatenate([vs, vs_prev], axis=1)
    pos2 = jnp.concatenate([pos, pos_prev], axis=1)               # (nc, 2c)

    scores = jnp.einsum("cqd,ckd->cqk", qs, ks2) * jnp.sqrt(jnp.float32(dh))
    # no self-attention on own position (shared-QK convention), causal mask
    self_mask = pos[:, :, None] == pos2[:, None, :]
    scores = jnp.where(self_mask, -1e5, scores)
    if causal:
        scores = jnp.where(pos[:, :, None] >= pos2[:, None, :], scores, -1e9)
    a = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("cqk,ckd->cqd", a, vs2).reshape(l, dh)
    return out[undo]


def multi_head_attention(cfg: ModelConfig, layer, feats, x, *, layer_idx):
    """x: (B, L, d_model) -> (B, L, d_model)."""
    b, l, d = x.shape
    h, dh = cfg.n_heads, cfg.d_head
    qkv = x @ layer["qkv"]["w"] + layer["qkv"]["b"]
    qkv = qkv.reshape(b, l, 3, h, dh).transpose(2, 0, 3, 1, 4)  # (3,B,H,L,dh)
    q, k, v = qkv[0], qkv[1], qkv[2]

    flat = lambda t: t.reshape(b * h, l, dh)
    q, k, v = flat(q), flat(k), flat(v)

    if cfg.attention == "identity":
        out = v
    elif cfg.attention == "exact":
        out = jax.vmap(functools.partial(_exact_head, causal=cfg.unidirectional,
                                         use_pallas=cfg.use_pallas))(q, k, v)
    elif cfg.attention == "lsh":
        out = jax.vmap(functools.partial(_lsh_head, rot=feats["rot"],
                                         causal=cfg.unidirectional,
                                         chunk=cfg.lsh_chunk))(q, k, v)
    elif cfg.attention.startswith("favor-"):
        f_name = cfg.attention.split("-", 1)[1]
        out = jax.vmap(functools.partial(
            _favor_head, w=feats["w"], b=feats["b"], f_name=f_name,
            causal=cfg.unidirectional, use_pallas=cfg.use_pallas))(q, k, v)
    else:
        raise ValueError(cfg.attention)

    out = out.reshape(b, h, l, dh).transpose(0, 2, 1, 3).reshape(b, l, d)
    return out @ layer["proj"]["w"] + layer["proj"]["b"]


# ---------------------------------------------------------------------------
# Transformer body
# ---------------------------------------------------------------------------

def _layer_norm(p, x, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return p["g"] * (x - mu) / jnp.sqrt(var + eps) + p["b"]


def _gelu(x):
    return 0.5 * x * (1.0 + jnp.tanh(0.7978845608 * (x + 0.044715 * x**3)))


def sinusoidal_positions(l, d):
    pos = np.arange(l)[:, None]
    i = np.arange(d)[None, :]
    angle = pos / np.power(10000.0, (2 * (i // 2)) / d)
    enc = np.where(i % 2 == 0, np.sin(angle), np.cos(angle))
    return jnp.asarray(enc, jnp.float32)


def forward(cfg: ModelConfig, params, feats, tokens):
    """tokens: (B, L) int32 -> logits (B, L, vocab)."""
    b, l = tokens.shape
    x = params["embed"][tokens] * jnp.sqrt(jnp.float32(cfg.d_model))
    x = x + sinusoidal_positions(l, cfg.d_model)[None]
    for i, layer in enumerate(params["layers"]):
        x = x + multi_head_attention(cfg, layer, feats, _layer_norm(layer["ln1"], x),
                                     layer_idx=i)
        hmid = _gelu(_layer_norm(layer["ln2"], x) @ layer["ff1"]["w"] + layer["ff1"]["b"])
        x = x + hmid @ layer["ff2"]["w"] + layer["ff2"]["b"]
    x = _layer_norm(params["lnf"], x)
    return x @ params["embed"].T  # weight-tied output head


def loss_fn(cfg: ModelConfig, params, feats, tokens, targets, weights):
    """Weighted CE. BID: tokens have [MASK]s, targets original AAs, weights
    1 at masked positions. UNI: targets = next token, weights 1 everywhere
    (minus padding)."""
    logits = forward(cfg, params, feats, tokens)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    wsum = jnp.sum(weights) + 1e-9
    loss = -jnp.sum(ll * weights) / wsum
    acc = jnp.sum((jnp.argmax(logits, -1) == targets) * weights) / wsum
    return loss, acc


# ---------------------------------------------------------------------------
# In-graph Adam train step (paper Appendix B.1 hyperparameters)
# ---------------------------------------------------------------------------

ADAM = dict(lr=1e-3, b1=0.9, b2=0.98, eps=1e-9, clip=0.5, wd=0.1)


def init_opt_state(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params),
            "step": jnp.zeros((), jnp.float32)}


def train_step(cfg: ModelConfig, params, opt, feats, tokens, targets, weights):
    (loss, acc), grads = jax.value_and_grad(
        lambda p: loss_fn(cfg, p, feats, tokens, targets, weights),
        has_aux=True)(params)

    # global-norm clip at 0.5
    leaves = jax.tree_util.tree_leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in leaves))
    scale = jnp.minimum(1.0, ADAM["clip"] / (gnorm + 1e-9))
    grads = jax.tree_util.tree_map(lambda g: g * scale, grads)

    step = opt["step"] + 1.0
    b1, b2 = ADAM["b1"], ADAM["b2"]
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, opt["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, opt["v"], grads)
    mhat_scale = 1.0 / (1.0 - b1 ** step)
    vhat_scale = 1.0 / (1.0 - b2 ** step)

    def upd(p, m_, v_):
        u = (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + ADAM["eps"])
        return p - ADAM["lr"] * (u + ADAM["wd"] * p)

    params = jax.tree_util.tree_map(upd, params, m, v)
    return params, {"m": m, "v": v, "step": step}, loss, acc


def eval_step(cfg: ModelConfig, params, feats, tokens, targets, weights):
    return loss_fn(cfg, params, feats, tokens, targets, weights)
