"""L1: Pallas kernels for FAVOR (Fast Attention Via Orthogonal Random
features), the paper's compute hot-spot.

Three kernels:
  * feature_map_pallas      — phi(x) = scale * f(xW^T + b) (+renormalizer),
                              blocked over rows (Eq. 9-11).
  * favor_bidirectional_pallas — D^-1 (Q'((K')^T C)), Eq. (13), two-phase:
                              phase 1 accumulates KV = (K')^T C over L
                              blocks, phase 2 emits output row blocks.
  * favor_unidirectional_pallas — Alg. 1 prefix-sum branch: a sequential
                              grid over L blocks carrying the running
                              G^PS = sum_j K'_j C_j^T in an accumulator
                              output, with an in-block tril correction.

All kernels are 2D (L x ...) — batch and head dims are vmapped by the
caller (pallas_call has a batching rule). interpret=True everywhere: the
CPU PJRT plugin cannot execute Mosaic custom-calls, so kernels lower to
plain HLO (see DESIGN.md §Hardware-Adaptation for the TPU mapping:
accumulators are the VMEM-resident M x (d+1) running state, row blocks are
the HBM->VMEM schedule expressed by the BlockSpecs).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True  # CPU PJRT: Mosaic custom-calls unavailable (see module doc)

_F = {
    "relu": lambda t: jnp.maximum(t, 0.0),
    "sigmoid": lambda t: 1.0 / (1.0 + jnp.exp(-t)),
    "exp": jnp.exp,
    "abs": jnp.abs,
    "gelu": lambda t: 0.5 * t * (1.0 + jnp.tanh(0.7978845608 * (t + 0.044715 * t**3))),
    "cos": jnp.cos,
    "tanh": jnp.tanh,
    "identity": lambda t: t,
}


def _block(l, want):
    """Largest divisor of l that is <= want (grid blocks must tile L)."""
    b = min(want, l)
    while l % b != 0:
        b -= 1
    return b


# ---------------------------------------------------------------------------
# Feature map kernel
# ---------------------------------------------------------------------------

def _feature_kernel(x_ref, w_ref, b_ref, o_ref, *, f_name, softmax_renorm, r, scale, eps):
    x = x_ref[...]
    z = x @ w_ref[...].T + b_ref[...][None, :]
    feats = scale * _F[f_name](z) + eps
    if softmax_renorm:
        # D_Q / D_K diagonal renormalizer of Eq. (5)-(6): exp(||x||^2 / r)
        diag = jnp.exp(jnp.sum(x * x, axis=-1, keepdims=True) / r)
        feats = diag * feats
    o_ref[...] = feats


def feature_map_pallas(x, w, b, *, f_name="cos", softmax_renorm=True,
                       kernel_eps=0.0, block_l=128):
    """phi'(x) rows for all L tokens. x: (L, d), w: (M, d), b: (M,)."""
    l, d = x.shape
    m = w.shape[0]
    blk = _block(l, block_l)
    if softmax_renorm:
        scale = float((2.0 / m) ** 0.5)
    else:
        scale = float(1.0 / m ** 0.5)
    r = 2.0 * float(d) ** 0.5
    kern = functools.partial(_feature_kernel, f_name=f_name,
                             softmax_renorm=softmax_renorm, r=r,
                             scale=scale, eps=kernel_eps)
    return pl.pallas_call(
        kern,
        grid=(l // blk,),
        in_specs=[
            pl.BlockSpec((blk, d), lambda i: (i, 0)),
            pl.BlockSpec((m, d), lambda i: (0, 0)),
            pl.BlockSpec((m,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((blk, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((l, m), x.dtype),
        interpret=INTERPRET,
    )(x, w, b)


# ---------------------------------------------------------------------------
# Bidirectional FAVOR: Eq. (13)
# ---------------------------------------------------------------------------

def _kv_accum_kernel(kp_ref, c_ref, kv_ref):
    """Phase 1: KV = (K')^T C accumulated over row blocks (constant out idx)."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        kv_ref[...] = jnp.zeros_like(kv_ref)

    kv_ref[...] += kp_ref[...].T @ c_ref[...]


def _bidir_out_kernel(qp_ref, kv_ref, o_ref, *, stabilizer):
    """Phase 2: out block = (Q'_blk KV)[:, :d] / (Q'_blk KV)[:, d]."""
    buf = qp_ref[...] @ kv_ref[...]                 # (blk, d+1)
    denom = buf[:, -1:] + stabilizer
    o_ref[...] = buf[:, :-1] / denom


def favor_bidirectional_pallas(qp, kp, v, *, stabilizer=1e-6, block_l=128):
    """Eq. (13): never materializes the L x L matrix. qp,kp: (L,M), v: (L,d)."""
    l, m = qp.shape
    d = v.shape[-1]
    blk = _block(l, block_l)
    c = jnp.concatenate([v, jnp.ones((l, 1), v.dtype)], axis=-1)  # C = [V 1]

    kv = pl.pallas_call(
        _kv_accum_kernel,
        grid=(l // blk,),
        in_specs=[
            pl.BlockSpec((blk, m), lambda i: (i, 0)),
            pl.BlockSpec((blk, d + 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((m, d + 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, d + 1), qp.dtype),
        interpret=INTERPRET,
    )(kp, c)

    return pl.pallas_call(
        functools.partial(_bidir_out_kernel, stabilizer=stabilizer),
        grid=(l // blk,),
        in_specs=[
            pl.BlockSpec((blk, m), lambda i: (i, 0)),
            pl.BlockSpec((m, d + 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((blk, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((l, d), v.dtype),
        interpret=INTERPRET,
    )(qp, kv)


# ---------------------------------------------------------------------------
# Unidirectional FAVOR: Alg. 1 prefix-sum branch
# ---------------------------------------------------------------------------

def _unidir_kernel(qp_ref, kp_ref, c_ref, o_ref, carry_ref, *, stabilizer):
    """Sequential grid over row blocks. carry_ref holds G^PS (M x (d+1)) of
    all *previous* blocks; the current block's causal interior is handled
    by an in-block tril correction:

      out_blk = Q'_blk @ carry + tril(Q'_blk K'_blk^T) @ C_blk
      carry  += K'_blk^T @ C_blk
    """
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    qp = qp_ref[...]
    kp = kp_ref[...]
    c = c_ref[...]
    blk = qp.shape[0]

    inter = qp @ carry_ref[...]                                   # (blk, d+1)
    scores = qp @ kp.T                                            # (blk, blk)
    row = jax.lax.broadcasted_iota(jnp.int32, (blk, blk), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (blk, blk), 1)
    intra = jnp.where(row >= col, scores, 0.0) @ c                # tril part
    buf = inter + intra
    denom = buf[:, -1:] + stabilizer
    o_ref[...] = buf[:, :-1] / denom
    carry_ref[...] += kp.T @ c


def favor_unidirectional_pallas(qp, kp, v, *, stabilizer=1e-6, block_l=128):
    """Causal FAVOR without the L x M x (d+1) G^PS tensor: the running
    prefix-sum lives in an M x (d+1) accumulator (the paper's Sec. 2.6
    'simple aggregation' variant, blocked for parallel in-block work).
    """
    l, m = qp.shape
    d = v.shape[-1]
    blk = _block(l, block_l)
    c = jnp.concatenate([v, jnp.ones((l, 1), v.dtype)], axis=-1)

    out, _carry = pl.pallas_call(
        functools.partial(_unidir_kernel, stabilizer=stabilizer),
        grid=(l // blk,),
        in_specs=[
            pl.BlockSpec((blk, m), lambda i: (i, 0)),
            pl.BlockSpec((blk, m), lambda i: (i, 0)),
            pl.BlockSpec((blk, d + 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((blk, d), lambda i: (i, 0)),
            pl.BlockSpec((m, d + 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((l, d), v.dtype),
            jax.ShapeDtypeStruct((m, d + 1), qp.dtype),
        ],
        interpret=INTERPRET,
    )(qp, kp, c)
    return out


# ---------------------------------------------------------------------------
# Exact-attention Pallas baseline (flash-style row blocks)
# ---------------------------------------------------------------------------

def _exact_kernel(q_ref, k_ref, v_ref, o_ref, *, causal, scale, block_l):
    i = pl.program_id(0)
    q = q_ref[...]
    scores = q @ k_ref[...].T * scale                  # (blk, L)
    if causal:
        blk = q.shape[0]
        l = scores.shape[1]
        row = jax.lax.broadcasted_iota(jnp.int32, (blk, l), 0) + i * block_l
        col = jax.lax.broadcasted_iota(jnp.int32, (blk, l), 1)
        scores = jnp.where(row >= col, scores, -jnp.inf)
    scores = scores - jnp.max(scores, axis=-1, keepdims=True)
    a = jnp.exp(scores)
    o_ref[...] = a @ v_ref[...] / jnp.sum(a, axis=-1, keepdims=True)


def exact_attention_pallas(q, k, v, *, causal=False, block_l=128):
    """O(L^2) baseline with numerically-stable softmax, row-blocked."""
    l, d = q.shape
    blk = _block(l, block_l)
    scale = 1.0 / float(d) ** 0.5
    return pl.pallas_call(
        functools.partial(_exact_kernel, causal=causal, scale=scale, block_l=blk),
        grid=(l // blk,),
        in_specs=[
            pl.BlockSpec((blk, d), lambda i: (i, 0)),
            pl.BlockSpec((l, d), lambda i: (0, 0)),
            pl.BlockSpec((l, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((blk, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((l, d), q.dtype),
        interpret=INTERPRET,
    )(q, k, v)


# ---------------------------------------------------------------------------
# Differentiable wrappers: Pallas forward + analytic linear-attention VJP
# ---------------------------------------------------------------------------
# pallas_call does not carry an autodiff rule. The backward pass is taken
# through the mathematically identical fused-jnp formulation (ref.py):
# same O(LMd) complexity, rematerialized (no residuals stored) — the
# standard pairing for hand-written attention kernels.

def _favor_ref(q, k, v, w, b, *, f_name, causal, softmax_renorm, kernel_eps,
               stabilizer):
    from compile.kernels import ref as ref_k
    if softmax_renorm:
        qp = ref_k.softmax_feature_map(q, w, b)
        kp = ref_k.softmax_feature_map(k, w, b)
    else:
        qp = ref_k.generalized_feature_map(q, w, f_name, kernel_eps=kernel_eps, b=b)
        kp = ref_k.generalized_feature_map(k, w, f_name, kernel_eps=kernel_eps, b=b)
    if causal:
        return ref_k.favor_unidirectional_scan(qp, kp, v, stabilizer=stabilizer)
    return ref_k.favor_bidirectional_linear(qp, kp, v, stabilizer=stabilizer)


def _exact_ref(q, k, v, *, causal):
    from compile.kernels import ref as ref_k
    if causal:
        return ref_k.exact_attention_unidirectional(q, k, v)
    return ref_k.exact_attention_bidirectional(q, k, v)


@functools.lru_cache(maxsize=None)
def make_favor_attention(f_name="cos", causal=False, softmax_renorm=True,
                         kernel_eps=0.0, stabilizer=1e-6, block_l=128):
    """Returns favor_attn(q, k, v, w, b): Pallas fwd, jnp-linear bwd."""
    kw = dict(f_name=f_name, causal=causal, softmax_renorm=softmax_renorm,
              kernel_eps=kernel_eps, stabilizer=stabilizer)

    @jax.custom_vjp
    def attn(q, k, v, w, b):
        return favor_attention_pallas(q, k, v, w, b, block_l=block_l, **kw)

    def fwd(q, k, v, w, b):
        return attn(q, k, v, w, b), (q, k, v, w, b)

    def bwd(res, g):
        q, k, v, w, b = res
        _, vjp = jax.vjp(lambda q_, k_, v_: _favor_ref(q_, k_, v_, w, b, **kw),
                         q, k, v)
        dq, dk, dv = vjp(g)
        return dq, dk, dv, None, None  # W, b are non-trainable features

    attn.defvjp(fwd, bwd)
    return attn


@functools.lru_cache(maxsize=None)
def make_exact_attention(causal=False, block_l=128):
    """Returns exact_attn(q, k, v): Pallas fwd, jnp bwd."""

    @jax.custom_vjp
    def attn(q, k, v):
        return exact_attention_pallas(q, k, v, causal=causal, block_l=block_l)

    def fwd(q, k, v):
        return attn(q, k, v), (q, k, v)

    def bwd(res, g):
        q, k, v = res
        _, vjp = jax.vjp(lambda q_, k_, v_: _exact_ref(q_, k_, v_, causal=causal),
                         q, k, v)
        return vjp(g)

    attn.defvjp(fwd, bwd)
    return attn


# ---------------------------------------------------------------------------
# Convenience: full FAVOR attention (feature map + linear attention)
# ---------------------------------------------------------------------------

def favor_attention_pallas(q, k, v, w, b, *, f_name="cos", causal=False,
                           softmax_renorm=True, kernel_eps=0.0,
                           stabilizer=1e-6, block_l=128):
    """phi-map Q and K, then apply linear attention. The composition the
    Performer model calls per (batch, head)."""
    qp = feature_map_pallas(q, w, b, f_name=f_name, softmax_renorm=softmax_renorm,
                            kernel_eps=kernel_eps, block_l=block_l)
    kp = feature_map_pallas(k, w, b, f_name=f_name, softmax_renorm=softmax_renorm,
                            kernel_eps=kernel_eps, block_l=block_l)
    if causal:
        return favor_unidirectional_pallas(qp, kp, v, stabilizer=stabilizer, block_l=block_l)
    return favor_bidirectional_pallas(qp, kp, v, stabilizer=stabilizer, block_l=block_l)
