"""Orthogonal random feature (ORF) projection matrices — Sec. 2.4.

Builds the W in phi(x) = c/sqrt(M) f(Wx + b) four ways:

  * iid    — rows ~ N(0, sigma^2 I_d) independently (plain Rahimi-Recht).
  * r-orf  — Gaussian orthogonal: stack ceil(M/d) independent d x d blocks,
             each = Gram-Schmidt(Q) of a Gaussian matrix with rows rescaled
             by chi_d-distributed norms so marginals stay N(0, I) [56].
  * h-orf  — SORF-style HD_3 HD_2 HD_1 products (normalized Hadamard x
             random diagonal signs), small bias -> 0 with d [13].
  * g-orf  — product of random Givens rotations [11].

numpy only (build-time; mirrored natively in rust/src/linalg for the
runtime analysis path — cross-checked in tests).
"""

import numpy as np


def _gram_schmidt(a):
    """Orthonormalize rows of a (d x d) via modified Gram-Schmidt."""
    q = a.astype(np.float64).copy()
    d = q.shape[0]
    for i in range(d):
        for j in range(i):
            q[i] -= np.dot(q[i], q[j]) * q[j]
        q[i] /= np.linalg.norm(q[i])
    return q


def _hadamard(d):
    """Normalized Hadamard matrix, d must be a power of two."""
    assert d & (d - 1) == 0, f"H-ORF needs power-of-two d, got {d}"
    h = np.array([[1.0]])
    while h.shape[0] < d:
        h = np.block([[h, h], [h, -h]])
    return h / np.sqrt(d)


def _orthogonal_block(rng, d, mechanism):
    if mechanism == "r-orf":
        block = _gram_schmidt(rng.standard_normal((d, d)))
    elif mechanism == "h-orf":
        h = _hadamard(d)
        block = np.eye(d)
        for _ in range(3):
            signs = rng.choice([-1.0, 1.0], size=d)
            block = (h * signs[None, :]) @ block
    elif mechanism == "g-orf":
        block = np.eye(d)
        # d*log(d) random Givens rotations approximate a Haar rotation [11]
        for _ in range(int(d * max(1, np.log2(d)))):
            i, j = rng.choice(d, size=2, replace=False)
            theta = rng.uniform(0.0, 2.0 * np.pi)
            c, s = np.cos(theta), np.sin(theta)
            gi, gj = block[i].copy(), block[j].copy()
            block[i] = c * gi - s * gj
            block[j] = s * gi + c * gj
    else:
        raise ValueError(mechanism)
    return block


def projection_matrix(m, d, *, mechanism="r-orf", sigma=1.0, seed=0,
                      chi_norms=True):
    """W in R^{M x d} with rows marginally ~ N(0, sigma^2 I_d).

    For orthogonal mechanisms, rows within each d x d block are exactly
    (r-orf) or approximately (h/g-orf) orthogonal; if M > d, blocks are
    drawn independently (orthogonality holds block-locally, as in [56]).
    """
    rng = np.random.default_rng(seed)
    if mechanism == "iid":
        w = rng.standard_normal((m, d))
    else:
        blocks = []
        remaining = m
        while remaining > 0:
            q = _orthogonal_block(rng, d, mechanism)
            if chi_norms:
                # rescale rows by chi_d norms so marginals match Gaussians
                norms = np.linalg.norm(rng.standard_normal((d, d)), axis=1)
                q = q * norms[:, None]
            take = min(remaining, d)
            blocks.append(q[:take])
            remaining -= take
        w = np.concatenate(blocks, axis=0)
    return (sigma * w).astype(np.float32)


def softmax_projection(m, d, *, mechanism="r-orf", seed=0):
    """W and b for the softmax-kernel features of Eq. (10): the Gaussian
    kernel of Eq. (7) has bandwidth sigma_B = d^{1/4}, equivalent to rows
    ~ N(0, I/sigma_B^2)... i.e. scale 1/d^{1/4}; b ~ Unif(0, 2pi)."""
    rng = np.random.default_rng(seed + 1)
    w = projection_matrix(m, d, mechanism=mechanism,
                          sigma=1.0 / float(d) ** 0.25, seed=seed)
    b = rng.uniform(0.0, 2.0 * np.pi, size=m).astype(np.float32)
    return w, b


def generalized_projection(m, d, *, mechanism="r-orf", seed=0):
    """W for generalized attention (Sec. 2.2): unit-Gaussian rows, b = 0."""
    w = projection_matrix(m, d, mechanism=mechanism, sigma=1.0, seed=seed)
    b = np.zeros(m, dtype=np.float32)
    return w, b
