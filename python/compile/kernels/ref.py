"""Pure-jnp correctness oracles for the FAVOR kernels.

These implement the paper's equations directly, with explicit O(L^2)
materialization where that is the clearest statement of the math. The
Pallas kernels in favor.py are tested against these in python/tests/.

Shapes follow the paper: Q, K, V in R^{L x d}; random features map to
R^{L x M}. Batch/head dims are handled by the callers via vmap.
"""

import jax.numpy as jnp


def exact_attention_bidirectional(q, k, v):
    """Eq. (1): Att(Q,K,V) = D^-1 A V, A = exp(QK^T / sqrt(d))."""
    d = q.shape[-1]
    a = jnp.exp(q @ k.T / jnp.sqrt(jnp.float32(d)))
    return a @ v / jnp.sum(a, axis=-1, keepdims=True)


def exact_attention_unidirectional(q, k, v):
    """Eq. (2): causal attention via tril(A)."""
    d = q.shape[-1]
    a = jnp.exp(q @ k.T / jnp.sqrt(jnp.float32(d)))
    a = jnp.tril(a)
    return a @ v / jnp.sum(a, axis=-1, keepdims=True)


def softmax_feature_map(x, w, b):
    """Eq. (10)/(11) combined with the D_Q/D_K diagonal renormalizers of
    Eq. (5)-(6): phi'(x) = exp(||x||^2 / r) * sqrt(2/M) cos(Wx + b),
    with r = 2*sqrt(d) and W rows drawn N(0, sigma^2 I_d), sigma^2 =
    sqrt(d) (the Gaussian kernel bandwidth sigma_B = d^{1/4} of Eq. (7)
    enters through W's scale).

    Returns the *renormalized* features Q' (or K') such that
    E[phi'(q) . phi'(k)] = exp(q.k / sqrt(d)) = A_ij.
    """
    d = x.shape[-1]
    m = w.shape[0]
    r = 2.0 * jnp.sqrt(jnp.float32(d))
    diag = jnp.exp(jnp.sum(x * x, axis=-1, keepdims=True) / r)
    feats = jnp.sqrt(2.0 / m) * jnp.cos(x @ w.T + b)
    return diag * feats


def generalized_feature_map(x, w, f_name, kernel_eps=1e-3, b=None):
    """Generalized attention features (Sec. 2.2): phi(x) = f(Wx + b)/sqrt(M)
    (+ kernel_eps for numerical stability, per the paper's Appendix B.3
    defaults: kernel = ReLU, kernel_epsilon = 1e-3). b is zero for GA but
    kept in the graph so the AOT I/O contract matches the Pallas path.
    """
    m = w.shape[0]
    z = x @ w.T
    if b is not None:
        z = z + b
    f = {
        "relu": lambda t: jnp.maximum(t, 0.0),
        "sigmoid": lambda t: 1.0 / (1.0 + jnp.exp(-t)),
        "exp": jnp.exp,
        "abs": jnp.abs,
        "gelu": lambda t: 0.5 * t * (1.0 + jnp.tanh(0.7978845608 * (t + 0.044715 * t**3))),
        "cos": jnp.cos,
        "tanh": jnp.tanh,
        "identity": lambda t: t,
    }[f_name]
    return f(z) / jnp.sqrt(jnp.float32(m)) + kernel_eps


def favor_bidirectional(qp, kp, v, stabilizer=1e-6):
    """Eq. (13) with A-hat = Q'(K')^T materialized explicitly (oracle)."""
    a = qp @ kp.T
    denom = jnp.sum(a, axis=-1, keepdims=True) + stabilizer
    return a @ v / denom


def favor_unidirectional(qp, kp, v, stabilizer=1e-6):
    """Eq. (14) oracle: tril(Q'(K')^T) applied to C = [V 1]."""
    a = jnp.tril(qp @ kp.T)
    denom = jnp.sum(a, axis=-1, keepdims=True) + stabilizer
    return a @ v / denom


def favor_bidirectional_linear(qp, kp, v, stabilizer=1e-6):
    """Eq. (13) in linear time: D^-1 (Q'((K')^T V)) without the LxL matrix.

    Identical math to favor_bidirectional (cross-checks the bracketing;
    this is the computation the Pallas kernel blocks).
    """
    kv = kp.T @ v                               # (M, d)
    ksum = jnp.sum(kp, axis=0)                  # (M,)
    num = qp @ kv                               # (L, d)
    denom = qp @ ksum[:, None] + stabilizer     # (L, 1)
    return num / denom


def favor_unidirectional_prefix(qp, kp, v, stabilizer=1e-6):
    """Alg. 1 unidirectional branch: prefix sums of G_j = K'_j C_j^T.

    Direct cumsum transcription of Eq. (14) — O(L·M·d) memory; kept as
    the oracle. Production paths use favor_unidirectional_scan below:
    xla_extension 0.5.1 (the AOT runtime) lowers cumsum to reduce-window,
    which its CPU backend executes in O(L^2) — catastrophic at L=1024+.
    """
    g = kp[:, :, None] * v[:, None, :]          # (L, M, d)
    gps = jnp.cumsum(g, axis=0)                 # (L, M, d)
    num = jnp.einsum("lm,lmd->ld", qp, gps)
    ksum = jnp.cumsum(kp, axis=0)               # (L, M)
    denom = jnp.sum(qp * ksum, axis=-1, keepdims=True) + stabilizer
    return num / denom


def favor_unidirectional_scan(qp, kp, v, stabilizer=1e-6, block=128):
    """Chunked lax.scan form of Eq. (14): the running M x (d+1) prefix
    state is carried across row blocks (the paper's Sec. 2.6 'simple
    aggregation'), with an in-block tril correction. Mathematically
    identical to favor_unidirectional_prefix; lowers to a while-loop that
    every XLA version executes in O(L·M·d)."""
    import jax

    l, m = qp.shape
    d = v.shape[-1]
    while l % block != 0:
        block //= 2
    c = jnp.concatenate([v, jnp.ones((l, 1), v.dtype)], axis=-1)  # (L, d+1)
    qb = qp.reshape(l // block, block, m)
    kb = kp.reshape(l // block, block, m)
    cb = c.reshape(l // block, block, d + 1)
    tril = jnp.tril(jnp.ones((block, block), qp.dtype))

    def step(carry, inputs):
        qblk, kblk, cblk = inputs
        inter = qblk @ carry                            # (blk, d+1)
        intra = (tril * (qblk @ kblk.T)) @ cblk         # causal interior
        buf = inter + intra
        return carry + kblk.T @ cblk, buf

    _, bufs = jax.lax.scan(step, jnp.zeros((m, d + 1), qp.dtype), (qb, kb, cb))
    buf = bufs.reshape(l, d + 1)
    return buf[:, :d] / (buf[:, d:] + stabilizer)
